//! Size-class sharded queues with admission control and backpressure.
//!
//! Requests are classified by *work units* (cost-matrix cells for
//! assignment, grid cells for max-flow) into three shards so a 512²
//! grid solve never sits in front of an n=30 real-time matching.  Each
//! shard is a bounded FIFO: when a shard is at depth the submit is
//! rejected synchronously with a [`RejectReason`] instead of queueing
//! unboundedly — the caller sheds load rather than timing out.
//!
//! Scheduling is by per-worker scan order (see [`scan_order`]): with two
//! or more workers, worker 0 is the reserved real-time lane (it never
//! picks up a Large job) and worker 1 prefers Large, so both tails of
//! the size distribution always have a worker whose first look is them.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::gridflow::CapacityDelta;
use crate::workloads::ProblemInstance;

use super::{ReplyError, SolveReply};

/// The three shard classes, by work units.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SizeClass {
    Small,
    Medium,
    Large,
}

impl SizeClass {
    pub const ALL: [SizeClass; 3] = [SizeClass::Small, SizeClass::Medium, SizeClass::Large];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            SizeClass::Small => 0,
            SizeClass::Medium => 1,
            SizeClass::Large => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SizeClass::Small => "small",
            SizeClass::Medium => "medium",
            SizeClass::Large => "large",
        }
    }
}

/// Sharding + admission parameters.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Work-unit ceiling of the Small class — the real-time lane
    /// (default 2048: matchings up to n = 45, grids up to 45²; the
    /// paper's §6 workload of n ≤ 30 lands here with room to spare,
    /// while any grid a solver would take visible time on does not).
    pub small_max_units: usize,
    /// Work-unit ceiling of the Medium class (default 8192: ≤ 90²
    /// grids); anything above is Large.
    pub medium_max_units: usize,
    /// Bounded per-shard queue depth; a full shard rejects.  Clamped
    /// to ≥ 1 by the queues (a 0-depth shard could never admit, which
    /// would turn closed-loop pacing into a spin).
    pub queue_depth: usize,
    /// Admission cap: instances above this many work units are rejected
    /// outright (default 1 << 20: 1024² grids).
    pub max_units: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            small_max_units: 2048,
            medium_max_units: 8192,
            queue_depth: 64,
            max_units: 1 << 20,
        }
    }
}

impl ShardConfig {
    pub fn classify(&self, units: usize) -> SizeClass {
        if units <= self.small_max_units {
            SizeClass::Small
        } else if units <= self.medium_max_units {
            SizeClass::Medium
        } else {
            SizeClass::Large
        }
    }
}

/// Why a submit was refused.  Every rejection is synchronous and
/// carries enough context for the client to adapt (shrink, retry
/// later, or route elsewhere).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// The shard for this size class is at its bounded depth.
    QueueFull { class: SizeClass, depth: usize },
    /// The instance exceeds the admission cap.
    TooLarge { units: usize, max_units: usize },
    /// The request's deadline passed before a worker picked it up, so
    /// the solve was shed instead of burning a worker on a result the
    /// client has already given up on.
    DeadlineExceeded,
    /// The pool is shutting down.
    ShuttingDown,
}

impl RejectReason {
    /// Short stable tag for breakdown tables ("queue-full=3 too-large=1"
    /// in the loadgen summary); the `Display` impl carries the detail.
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::QueueFull { .. } => "queue-full",
            RejectReason::TooLarge { .. } => "too-large",
            RejectReason::DeadlineExceeded => "deadline",
            RejectReason::ShuttingDown => "shutting-down",
        }
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QueueFull { class, depth } => write!(
                f,
                "queue full: {} shard at bounded depth {depth} (backpressure)",
                class.name()
            ),
            RejectReason::TooLarge { units, max_units } => write!(
                f,
                "instance too large: {units} work units exceed the admission cap {max_units}"
            ),
            RejectReason::DeadlineExceeded => {
                write!(f, "deadline exceeded before dispatch (request shed)")
            }
            RejectReason::ShuttingDown => write!(f, "solver pool is shutting down"),
        }
    }
}

/// What a queued job asks the worker to do.
pub(crate) enum JobPayload {
    /// Solve an instance cold; `open_session` additionally keeps the
    /// final residual state as a warm-start session (grid instances
    /// only — the reply's `session` field carries the new id).
    Solve {
        instance: ProblemInstance,
        open_session: bool,
    },
    /// Apply capacity deltas to an open session's residual cache and
    /// resume from the affected frontier.  Routed sticky (pinned) to
    /// the worker holding the cache.
    Update {
        session_id: u64,
        deltas: Vec<CapacityDelta>,
    },
}

/// A queued request, owned by a shard until a worker pops it.
pub(crate) struct QueuedJob {
    pub id: u64,
    pub class: SizeClass,
    pub payload: JobPayload,
    pub submitted: Instant,
    /// Absolute deadline; a job still queued past this instant is shed
    /// during the queue scans (push-when-full and every pop) with
    /// [`RejectReason::DeadlineExceeded`], and a solve in flight past
    /// it is cancelled at the next poll point.
    pub deadline: Option<Instant>,
    pub reply: std::sync::mpsc::Sender<Result<SolveReply, ReplyError>>,
}

impl QueuedJob {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |dl| now >= dl)
    }
}

struct State {
    queues: [VecDeque<QueuedJob>; 3],
    /// Per-worker pinned lanes for sticky session updates: a worker
    /// drains its own lane before the class scan, bounded like the
    /// class shards.
    pinned: Vec<VecDeque<QueuedJob>>,
    shutdown: bool,
}

/// The three bounded shard queues plus the worker wakeup condvar.
pub(crate) struct ShardedQueues {
    cfg: ShardConfig,
    state: Mutex<State>,
    cv: Condvar,
}

/// Which shards worker `worker` scans, in preference order.
///
/// * 1 worker: everything, small first.
/// * ≥ 2 workers: worker 0 is the reserved real-time lane — it never
///   takes a Large job, so a small matching is at worst one Medium
///   solve away from service.  Worker 1 is the heavy lane (Large
///   first), so Large jobs cannot starve either.  Remaining workers
///   alternate small-first / medium-first for load balance.
pub(crate) fn scan_order(worker: usize, workers: usize) -> &'static [SizeClass] {
    use SizeClass::*;
    if workers <= 1 {
        return &[Small, Medium, Large];
    }
    match worker {
        0 => &[Small, Medium],
        1 => &[Large, Medium, Small],
        w if w % 2 == 0 => &[Small, Medium, Large],
        _ => &[Medium, Small, Large],
    }
}

/// Whether a job may ride in a grid micro-batch: a plain cold grid
/// solve.  Session opens keep per-worker state and session updates are
/// sticky — both stay on the per-instance path.
fn batchable(job: &QueuedJob) -> bool {
    matches!(
        &job.payload,
        JobPayload::Solve {
            instance: ProblemInstance::Grid(_),
            open_session: false,
        }
    )
}

/// Move every already-expired job out of `q` into `shed` (the caller
/// replies `DeadlineExceeded` and counts the misses, outside the lock).
fn drain_expired(q: &mut VecDeque<QueuedJob>, now: Instant, shed: &mut Vec<QueuedJob>) {
    let mut i = 0;
    while i < q.len() {
        if q[i].expired(now) {
            shed.push(q.remove(i).expect("index in bounds"));
        } else {
            i += 1;
        }
    }
}

impl ShardedQueues {
    pub fn new(mut cfg: ShardConfig, workers: usize) -> Self {
        cfg.queue_depth = cfg.queue_depth.max(1);
        Self {
            cfg,
            state: Mutex::new(State {
                queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                pinned: (0..workers).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn config(&self) -> &ShardConfig {
        &self.cfg
    }

    /// Admit `job` into its shard, or hand it back with the reason.
    ///
    /// A full shard is swept for already-expired jobs first (into
    /// `shed`): dead work must not hold depth slots and turn into
    /// spurious `QueueFull` rejections for live requests while the
    /// workers are stalled.
    pub fn push(
        &self,
        job: QueuedJob,
        shed: &mut Vec<QueuedJob>,
    ) -> Result<(), (QueuedJob, RejectReason)> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err((job, RejectReason::ShuttingDown));
        }
        let depth = self.cfg.queue_depth;
        let q = &mut st.queues[job.class.index()];
        if q.len() >= depth {
            drain_expired(q, Instant::now(), shed);
        }
        if q.len() >= depth {
            let reason = RejectReason::QueueFull {
                class: job.class,
                depth,
            };
            return Err((job, reason));
        }
        q.push_back(job);
        drop(st);
        // notify_all: the woken worker must be one whose scan order
        // includes this shard (worker 0 never serves Large).
        self.cv.notify_all();
        Ok(())
    }

    /// Admit a sticky job into `worker`'s pinned lane (session updates
    /// must reach the worker holding the residual cache), with the same
    /// bounded depth and expired-sweep as the class shards.
    pub fn push_pinned(
        &self,
        job: QueuedJob,
        worker: usize,
        shed: &mut Vec<QueuedJob>,
    ) -> Result<(), (QueuedJob, RejectReason)> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err((job, RejectReason::ShuttingDown));
        }
        if worker >= st.pinned.len() {
            // Directory pointed at a worker this pool does not have
            // (can only happen across a restart); treat as shed.
            return Err((job, RejectReason::ShuttingDown));
        }
        let depth = self.cfg.queue_depth;
        let q = &mut st.pinned[worker];
        if q.len() >= depth {
            drain_expired(q, Instant::now(), shed);
        }
        if q.len() >= depth {
            let reason = RejectReason::QueueFull {
                class: job.class,
                depth,
            };
            return Err((job, reason));
        }
        q.push_back(job);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    /// Block until a job this worker may take is available.
    ///
    /// Jobs whose deadline already passed are moved into `shed` during
    /// the scan instead of being returned: they never consume a worker
    /// wakeup or occupy a depth slot a live request could use.  Returns
    /// `None` in two cases the caller must distinguish: `shed` is
    /// non-empty (expired jobs were swept — reply to them and call
    /// `pop` again) or, with `shed` empty, the pool is shutting down
    /// and this worker's shards are drained.
    pub fn pop(
        &self,
        worker: usize,
        workers: usize,
        shed: &mut Vec<QueuedJob>,
    ) -> Option<QueuedJob> {
        let order = scan_order(worker, workers);
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            // The worker's pinned session lane first: sticky updates
            // are small and latency-sensitive, and nobody else can
            // serve them.
            if worker < st.pinned.len() {
                while let Some(job) = st.pinned[worker].pop_front() {
                    if job.expired(now) {
                        shed.push(job);
                        continue;
                    }
                    return Some(job);
                }
            }
            for &class in order {
                while let Some(job) = st.queues[class.index()].pop_front() {
                    if job.expired(now) {
                        shed.push(job);
                        continue;
                    }
                    return Some(job);
                }
            }
            // Hand shed jobs back *before* blocking: their rejection
            // replies must not wait for the next live submit.  The
            // caller replies to them and calls `pop` again.
            if !shed.is_empty() {
                return None;
            }
            if st.shutdown {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Pop a micro-batch: the seed job comes from the normal scan
    /// ([`ShardedQueues::pop`] semantics, including the pinned lane and
    /// expired-job shedding), then — when the seed is a *plain grid
    /// solve* (no session open) and `max > 1` — up to `max - 1`
    /// compatible followers are cut from the **front** of the seed's
    /// class shard.  Compatible = same class, grid family, plain solve;
    /// the cut stops at the first live incompatible job, so nothing is
    /// reordered past anything else in its shard.  Expired jobs met
    /// while cutting go to `shed` (answered `DeadlineExceeded`, never
    /// solved) — each member keeps its own deadline; the batch inherits
    /// nothing from its slackest member.
    ///
    /// If the cut comes up short and `linger` is nonzero, the worker
    /// waits on the condvar up to the linger deadline for more
    /// compatible arrivals.  The reserved real-time lane (worker 0 when
    /// `workers ≥ 2`) **never lingers** — its job is latency, and a
    /// seed popped there dispatches immediately with whatever was
    /// already queued.
    ///
    /// Returns `None` exactly when [`ShardedQueues::pop`] would: shed
    /// jobs to reply to (non-empty `shed`), or shutdown.
    pub fn pop_batch(
        &self,
        worker: usize,
        workers: usize,
        max: usize,
        linger: std::time::Duration,
        shed: &mut Vec<QueuedJob>,
    ) -> Option<Vec<QueuedJob>> {
        let seed = self.pop(worker, workers, shed)?;
        if max <= 1 || !batchable(&seed) {
            return Some(vec![seed]);
        }
        let class = seed.class;
        let mut batch = vec![seed];
        let realtime = workers >= 2 && worker == 0;
        let start = Instant::now();
        let deadline = start + linger;
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            let q = &mut st.queues[class.index()];
            while batch.len() < max {
                match q.front() {
                    Some(j) if j.expired(now) => {
                        shed.push(q.pop_front().expect("front exists"));
                    }
                    Some(j) if batchable(j) => {
                        batch.push(q.pop_front().expect("front exists"));
                    }
                    _ => break,
                }
            }
            if batch.len() >= max || realtime || st.shutdown {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        drop(st);
        // One cut record per batch path taken (singletons included: the
        // histogram is the cut-size distribution, and a lone seed after
        // a full linger is signal, not noise).
        let (mut hmax, mut wmax, mut logical) = (0u64, 0u64, 0u64);
        for job in &batch {
            if let JobPayload::Solve {
                instance: ProblemInstance::Grid(net),
                ..
            } = &job.payload
            {
                hmax = hmax.max(net.height as u64);
                wmax = wmax.max(net.width as u64);
                logical += (net.height * net.width) as u64;
            }
        }
        crate::obs::record_batch_cut(
            batch.len(),
            batch.len() as u64 * hmax * wmax,
            logical,
            start.elapsed().as_secs_f64(),
        );
        Some(batch)
    }

    /// Begin shutdown: no new admissions; workers drain then exit.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Current depth of one class shard — the introspection gauges
    /// (`flowmatch_shard_depth{class=...}`) read this on every snapshot.
    pub fn depth(&self, class: SizeClass) -> usize {
        self.state.lock().unwrap().queues[class.index()].len()
    }

    /// Total depth of the per-worker pinned session lanes.
    pub fn pinned_depth(&self) -> usize {
        self.state
            .lock()
            .unwrap()
            .pinned
            .iter()
            .map(VecDeque::len)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::AssignmentInstance;

    fn job(class: SizeClass) -> QueuedJob {
        let (tx, _rx) = std::sync::mpsc::channel();
        QueuedJob {
            id: 0,
            class,
            payload: JobPayload::Solve {
                instance: ProblemInstance::Assignment(AssignmentInstance::new(2, vec![0; 4])),
                open_session: false,
            },
            submitted: Instant::now(),
            deadline: None,
            reply: tx,
        }
    }

    fn expired_job(class: SizeClass) -> QueuedJob {
        let mut j = job(class);
        // An instant already in the past: expired the moment it queues.
        j.deadline = Some(Instant::now() - std::time::Duration::from_millis(10));
        j
    }

    fn push(q: &ShardedQueues, j: QueuedJob) -> Result<(), RejectReason> {
        let mut shed = Vec::new();
        let r = q.push(j, &mut shed).map_err(|(_, reason)| reason);
        assert!(shed.is_empty(), "unexpected shed during test push");
        r
    }

    fn pop(q: &ShardedQueues, worker: usize, workers: usize) -> Option<QueuedJob> {
        let mut shed = Vec::new();
        let got = q.pop(worker, workers, &mut shed);
        assert!(shed.is_empty(), "unexpected shed during test pop");
        got
    }

    /// A plain cold grid solve — the only payload shape that batches.
    fn grid_job(class: SizeClass, id: u64) -> QueuedJob {
        let mut j = job(class);
        j.id = id;
        j.payload = JobPayload::Solve {
            instance: ProblemInstance::Grid(crate::graph::GridNetwork::zeros(2, 2)),
            open_session: false,
        };
        j
    }

    #[test]
    fn pop_batch_cuts_compatible_plain_grid_solves() {
        let q = ShardedQueues::new(ShardConfig::default(), 1);
        let mut shed = Vec::new();
        for id in 0..3 {
            assert!(q.push(grid_job(SizeClass::Small, id), &mut shed).is_ok());
        }
        // An assignment job interrupts the run; a grid job sits behind it.
        assert!(q.push(job(SizeClass::Small), &mut shed).is_ok());
        assert!(q.push(grid_job(SizeClass::Small, 9), &mut shed).is_ok());
        let batch = q
            .pop_batch(0, 1, 8, std::time::Duration::ZERO, &mut shed)
            .unwrap();
        assert!(shed.is_empty());
        let ids: Vec<u64> = batch.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "cut stops at the first incompatible job");
        // FIFO past the cut point is intact: assignment first, then the
        // grid job that was parked behind it.
        let next = pop(&q, 0, 1).unwrap();
        assert!(!batchable(&next), "assignment job preserved its slot");
        assert_eq!(pop(&q, 0, 1).unwrap().id, 9);
    }

    /// Satellite regression: an expired job inside a cut batch is shed
    /// (its reply is `DeadlineExceeded`, handled by the pool from
    /// `shed`) while its batchmates are returned for solving.  The
    /// batch never inherits the slackest member's deadline — each
    /// member keeps its own.
    #[test]
    fn expired_mate_in_cut_batch_is_shed_not_solved() {
        let q = ShardedQueues::new(ShardConfig::default(), 1);
        let mut shed = Vec::new();
        assert!(q.push(grid_job(SizeClass::Small, 1), &mut shed).is_ok());
        let mut dead = grid_job(SizeClass::Small, 2);
        dead.deadline = Some(Instant::now() - std::time::Duration::from_millis(10));
        assert!(q.push(dead, &mut shed).is_ok());
        assert!(q.push(grid_job(SizeClass::Small, 3), &mut shed).is_ok());
        let batch = q
            .pop_batch(0, 1, 8, std::time::Duration::ZERO, &mut shed)
            .unwrap();
        let ids: Vec<u64> = batch.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![1, 3], "live batchmates solve");
        assert_eq!(shed.len(), 1, "expired mate shed, not solved");
        assert_eq!(shed[0].id, 2);
    }

    /// The reserved real-time lane dispatches immediately: no linger
    /// wait even when the batch is short of `max`.
    #[test]
    fn pop_batch_realtime_lane_never_lingers() {
        let q = ShardedQueues::new(ShardConfig::default(), 2);
        let mut shed = Vec::new();
        assert!(q.push(grid_job(SizeClass::Small, 1), &mut shed).is_ok());
        let t0 = Instant::now();
        let batch = q
            .pop_batch(0, 2, 8, std::time::Duration::from_millis(500), &mut shed)
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(250),
            "real-time lane lingered"
        );
    }

    /// A non-realtime worker lingers up to the deadline and picks up a
    /// compatible late arrival.
    #[test]
    fn pop_batch_lingers_for_late_arrivals() {
        use std::sync::Arc;
        let q = Arc::new(ShardedQueues::new(ShardConfig::default(), 1));
        let mut shed = Vec::new();
        assert!(q.push(grid_job(SizeClass::Small, 1), &mut shed).is_ok());
        let q2 = Arc::clone(&q);
        let late = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            let mut shed = Vec::new();
            assert!(q2.push(grid_job(SizeClass::Small, 2), &mut shed).is_ok());
        });
        let batch = q
            .pop_batch(0, 1, 2, std::time::Duration::from_millis(2_000), &mut shed)
            .unwrap();
        late.join().unwrap();
        let ids: Vec<u64> = batch.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![1, 2], "linger caught the late arrival");
    }

    /// Non-batchable seeds (assignment; grid session opens) never grow
    /// a batch, even with compatible jobs queued behind them.
    #[test]
    fn non_batchable_seed_dispatches_alone() {
        let q = ShardedQueues::new(ShardConfig::default(), 1);
        let mut shed = Vec::new();
        assert!(q.push(job(SizeClass::Small), &mut shed).is_ok());
        assert!(q.push(grid_job(SizeClass::Small, 7), &mut shed).is_ok());
        let batch = q
            .pop_batch(0, 1, 8, std::time::Duration::ZERO, &mut shed)
            .unwrap();
        assert_eq!(batch.len(), 1, "assignment seed stays solo");

        let mut open = grid_job(SizeClass::Small, 8);
        if let JobPayload::Solve { open_session, .. } = &mut open.payload {
            *open_session = true;
        }
        assert!(q.push(open, &mut shed).is_ok());
        assert!(q.push(grid_job(SizeClass::Small, 9), &mut shed).is_ok());
        // Drain the id-7 job left from the first cut-stop.
        assert_eq!(pop(&q, 0, 1).unwrap().id, 7);
        let batch = q
            .pop_batch(0, 1, 8, std::time::Duration::ZERO, &mut shed)
            .unwrap();
        assert_eq!(batch.len(), 1, "session-open seed stays solo");
        assert_eq!(batch[0].id, 8);
    }

    #[test]
    fn classification_boundaries() {
        let cfg = ShardConfig {
            small_max_units: 100,
            medium_max_units: 1000,
            ..Default::default()
        };
        assert_eq!(cfg.classify(1), SizeClass::Small);
        assert_eq!(cfg.classify(100), SizeClass::Small);
        assert_eq!(cfg.classify(101), SizeClass::Medium);
        assert_eq!(cfg.classify(1000), SizeClass::Medium);
        assert_eq!(cfg.classify(1001), SizeClass::Large);
    }

    #[test]
    fn bounded_depth_rejects() {
        let q = ShardedQueues::new(
            ShardConfig {
                queue_depth: 2,
                ..Default::default()
            },
            1,
        );
        assert!(push(&q, job(SizeClass::Small)).is_ok());
        assert!(push(&q, job(SizeClass::Small)).is_ok());
        let reason = push(&q, job(SizeClass::Small)).unwrap_err();
        assert_eq!(
            reason,
            RejectReason::QueueFull {
                class: SizeClass::Small,
                depth: 2
            }
        );
        // Other shards are independent.
        assert!(push(&q, job(SizeClass::Large)).is_ok());
        assert_eq!(q.depth(SizeClass::Small), 2);
        assert_eq!(q.depth(SizeClass::Large), 1);
    }

    #[test]
    fn shutdown_rejects_new_and_drains_old() {
        let q = ShardedQueues::new(ShardConfig::default(), 1);
        assert!(push(&q, job(SizeClass::Medium)).is_ok());
        q.shutdown();
        let reason = push(&q, job(SizeClass::Small)).unwrap_err();
        assert_eq!(reason, RejectReason::ShuttingDown);
        // The queued job is still drained...
        assert!(pop(&q, 0, 1).is_some());
        // ...then workers see the shutdown.
        assert!(pop(&q, 0, 1).is_none());
    }

    #[test]
    fn reserved_lane_never_scans_large() {
        assert!(!scan_order(0, 4).contains(&SizeClass::Large));
        assert_eq!(scan_order(1, 4)[0], SizeClass::Large);
        assert_eq!(scan_order(0, 1), &SizeClass::ALL[..]);
        for w in 0..8 {
            assert!(scan_order(w, 8).contains(&SizeClass::Small));
        }
    }

    #[test]
    fn pop_prefers_small_on_lane_zero() {
        let q = ShardedQueues::new(ShardConfig::default(), 2);
        push(&q, job(SizeClass::Medium)).unwrap();
        push(&q, job(SizeClass::Small)).unwrap();
        let got = pop(&q, 0, 2).unwrap();
        assert_eq!(got.class, SizeClass::Small);
        let got = pop(&q, 0, 2).unwrap();
        assert_eq!(got.class, SizeClass::Medium);
    }

    #[test]
    fn zero_depth_clamped_to_one() {
        let q = ShardedQueues::new(
            ShardConfig {
                queue_depth: 0,
                ..Default::default()
            },
            1,
        );
        assert!(push(&q, job(SizeClass::Small)).is_ok());
        assert!(push(&q, job(SizeClass::Small)).is_err());
    }

    /// Regression (deadline-clogged shards): a shard full of jobs whose
    /// deadlines already passed must not reject a live request — the
    /// full-shard push sweeps the dead jobs into `shed` and admits it.
    #[test]
    fn full_shard_of_expired_jobs_admits_fresh_request() {
        let q = ShardedQueues::new(
            ShardConfig {
                queue_depth: 2,
                ..Default::default()
            },
            1,
        );
        // Not full yet, so the expired jobs queue without a sweep.
        push(&q, expired_job(SizeClass::Small)).unwrap();
        push(&q, expired_job(SizeClass::Small)).unwrap();
        assert_eq!(q.depth(SizeClass::Small), 2);
        let mut shed = Vec::new();
        q.push(job(SizeClass::Small), &mut shed).unwrap();
        assert_eq!(shed.len(), 2, "both expired jobs swept");
        assert!(shed.iter().all(|j| j.expired(Instant::now())));
        assert_eq!(q.depth(SizeClass::Small), 1);
        // The admitted job is live and served.
        let got = pop(&q, 0, 1).unwrap();
        assert!(got.deadline.is_none());
    }

    /// Pop sweeps expired jobs instead of returning them, and — when the
    /// sweep leaves nothing live — returns `None` with `shed` populated
    /// rather than blocking, so their rejection replies go out now.
    #[test]
    fn pop_sheds_expired_jobs_without_blocking() {
        let q = ShardedQueues::new(ShardConfig::default(), 1);
        push(&q, expired_job(SizeClass::Small)).unwrap();
        push(&q, job(SizeClass::Small)).unwrap();
        let mut shed = Vec::new();
        let got = q.pop(0, 1, &mut shed).unwrap();
        assert!(got.deadline.is_none(), "live job served");
        assert_eq!(shed.len(), 1, "expired job swept in the same scan");
        // Only expired jobs left: pop must hand them back, not block.
        push(&q, expired_job(SizeClass::Medium)).unwrap();
        let mut shed = Vec::new();
        assert!(q.pop(0, 1, &mut shed).is_none());
        assert_eq!(shed.len(), 1);
    }

    #[test]
    fn pinned_lane_is_sticky_and_preferred() {
        let q = ShardedQueues::new(ShardConfig::default(), 2);
        push(&q, job(SizeClass::Small)).unwrap();
        let mut shed = Vec::new();
        q.push_pinned(job(SizeClass::Medium), 1, &mut shed).unwrap();
        assert!(shed.is_empty());
        // Worker 0 never sees worker 1's pinned job.
        assert_eq!(pop(&q, 0, 2).unwrap().class, SizeClass::Small);
        // Worker 1 drains its pinned lane before the class shards.
        push(&q, job(SizeClass::Large)).unwrap();
        assert_eq!(pop(&q, 1, 2).unwrap().class, SizeClass::Medium);
        assert_eq!(pop(&q, 1, 2).unwrap().class, SizeClass::Large);
        // Pinned pushes to a worker the pool does not have are refused.
        let mut shed = Vec::new();
        let (_, reason) = q.push_pinned(job(SizeClass::Small), 7, &mut shed).unwrap_err();
        assert_eq!(reason, RejectReason::ShuttingDown);
    }

    #[test]
    fn default_boundaries_separate_the_demo_workloads() {
        let cfg = ShardConfig::default();
        assert_eq!(cfg.classify(30 * 30), SizeClass::Small); // §6 matchings
        assert_eq!(cfg.classify(48 * 48), SizeClass::Medium); // demo grids
        assert_eq!(cfg.classify(96 * 96), SizeClass::Large); // oversized grids
    }

    #[test]
    fn reject_reasons_render() {
        let full = RejectReason::QueueFull {
            class: SizeClass::Small,
            depth: 4,
        };
        assert!(full.to_string().contains("queue full"));
        assert_eq!(full.label(), "queue-full");
        let large = RejectReason::TooLarge {
            units: 9,
            max_units: 4,
        };
        assert!(large.to_string().contains("too large"));
        assert_eq!(large.label(), "too-large");
        assert_eq!(RejectReason::ShuttingDown.label(), "shutting-down");
        assert_eq!(RejectReason::DeadlineExceeded.label(), "deadline");
        assert!(RejectReason::DeadlineExceeded
            .to_string()
            .contains("deadline exceeded"));
    }
}
