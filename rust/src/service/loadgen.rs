//! Load generation and trace replay against the solver pool.
//!
//! Builds on `workloads::traces`: a [`MixedTrace`] (assignment stream +
//! grid stream, arrival-sorted) is replayed either open-loop (honour
//! arrival offsets — the §6 real-time shape) or closed-loop (submit as
//! fast as admission control allows — the throughput shape).  The
//! replay records client-side what the service promised: per-request
//! latency split by family and p50/p95/p99 summaries, plus the reject
//! count that the bounded shards produced.
//!
//! [`replay_spawn_baseline`] is the anti-pattern the pool replaces — a
//! fresh thread and fresh solver state per request — kept as the
//! benchmark baseline for `bench_service`.

use std::time::Duration;

use crate::util::stats::Summary;
use crate::util::{CancelToken, Timer};
use crate::workloads::{DeltaKind, DeltaTrace, MixedTrace, ProblemInstance};

use super::pool::SolverPool;
use super::router::{RouterConfig, WorkerBackends};
use super::shard::{RejectReason, ShardConfig};
use super::SolveReply;

/// Why a replayed request produced no reply — the service-wide typed
/// reply error, re-exported under the historical loadgen name.
pub use super::ReplyError as ReplayError;

/// Outcome of one replay run, measured at the client.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub sent: usize,
    pub ok: usize,
    pub rejected: usize,
    pub failed: usize,
    /// Requests whose reply channel was dropped without an answer —
    /// zero unless the service lost a request worker mid-solve.
    pub lost: usize,
    /// Retry attempts the service made across all replies (successes
    /// and exhausted failures both report their count).
    pub retries: u64,
    /// Candidate backends the router skipped because a circuit breaker
    /// was open.
    pub breaker_skips: u64,
    /// Requests shed because their deadline passed before dispatch.
    pub deadline_misses: usize,
    pub wall_seconds: f64,
    /// Served requests per wall-clock second.
    pub throughput_rps: f64,
    pub overall: Option<Summary>,
    pub assign: Option<Summary>,
    pub grid: Option<Summary>,
    /// Rejections broken down by [`RejectReason::label`] (queue-full /
    /// too-large / shutting-down), so backpressure behaviour is visible
    /// in summaries without reading per-request traces.
    pub reject_reasons: Vec<(&'static str, usize)>,
    /// Sum of every served reply's phase breakdown — where the run's
    /// solve time actually went (queue wait, wave compute, host
    /// rounds), client-side.  Zero when no reply carried a breakdown
    /// (e.g. the spawn baseline).
    pub phases: crate::obs::PhaseBreakdown,
    /// Per-request outcomes in trace order, for oracle verification by
    /// the caller.
    pub replies: Vec<(usize, Result<SolveReply, ReplayError>)>,
}

impl ReplayOutcome {
    fn from_replies(replies: Vec<(usize, Result<SolveReply, ReplayError>)>, wall: f64) -> Self {
        let sent = replies.len();
        let mut assign = Vec::new();
        let mut grid = Vec::new();
        let mut rejected = 0usize;
        let mut failed = 0usize;
        let mut lost = 0usize;
        let mut retries = 0u64;
        let mut breaker_skips = 0u64;
        let mut deadline_misses = 0usize;
        let mut reasons: std::collections::BTreeMap<&'static str, usize> =
            std::collections::BTreeMap::new();
        let mut phases = crate::obs::PhaseBreakdown::default();
        for (_, r) in &replies {
            match r {
                Ok(reply) => {
                    retries += u64::from(reply.retries);
                    breaker_skips += u64::from(reply.breaker_skips);
                    if let Some(p) = &reply.phases {
                        phases.merge(p);
                    }
                    if reply.outcome.family() == "assignment" {
                        assign.push(reply.latency);
                    } else {
                        grid.push(reply.latency);
                    }
                }
                Err(ReplayError::Rejected(reason)) => {
                    rejected += 1;
                    if matches!(reason, RejectReason::DeadlineExceeded) {
                        deadline_misses += 1;
                    }
                    *reasons.entry(reason.label()).or_insert(0) += 1;
                }
                Err(ReplayError::Failed { retries: r, .. }) => {
                    failed += 1;
                    retries += u64::from(*r);
                }
                Err(ReplayError::Lost) => {
                    failed += 1;
                    lost += 1;
                }
                // Cold-fallback bookkeeping lives in `replay_sessions`;
                // in a plain replay an evicted session is just a miss.
                Err(ReplayError::SessionEvicted) => failed += 1,
            }
        }
        let ok = assign.len() + grid.len();
        let all: Vec<f64> = assign.iter().chain(grid.iter()).copied().collect();
        Self {
            sent,
            ok,
            rejected,
            failed,
            lost,
            retries,
            breaker_skips,
            deadline_misses,
            wall_seconds: wall,
            throughput_rps: if wall > 0.0 { ok as f64 / wall } else { 0.0 },
            overall: Summary::of(&all),
            assign: Summary::of(&assign),
            grid: Summary::of(&grid),
            reject_reasons: reasons.into_iter().collect(),
            phases,
            replies,
        }
    }
}

/// Replay `trace` through `pool`.
///
/// Open-loop honours arrival offsets and records rejections as shed
/// load — a real-time client cannot wait, so backpressure is the
/// service protecting its latency.  Closed-loop submits as fast as
/// admission control allows: on `QueueFull` it *paces* (briefly waits
/// and retries) instead of shedding, so a closed-loop run measures
/// throughput over the whole trace rather than over whichever prefix
/// fit the queue depth.
pub fn replay(pool: &SolverPool, trace: &MixedTrace, open_loop: bool) -> ReplayOutcome {
    let start = Timer::start();
    let mut pending = Vec::with_capacity(trace.len());
    for req in &trace.requests {
        if open_loop {
            let now = start.elapsed();
            if req.arrival > now {
                std::thread::sleep(std::time::Duration::from_secs_f64(req.arrival - now));
            }
        }
        let deadline = req.deadline.map(Duration::from_secs_f64);
        let slot = loop {
            match pool.try_submit_with_deadline(req.instance.clone(), deadline) {
                Ok(rx) => break Ok(rx),
                // Pace only when something is draining: a 0-worker
                // pool (admission-only test mode) must still reject.
                Err(RejectReason::QueueFull { .. }) if !open_loop && pool.workers() > 0 => {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(reason) => break Err(reason),
            }
        };
        match slot {
            Ok(rx) => pending.push((req.id, Ok(rx))),
            Err(reason) => pending.push((req.id, Err(ReplayError::Rejected(reason)))),
        }
    }
    let mut replies = Vec::with_capacity(pending.len());
    for (id, slot) in pending {
        let outcome = match slot {
            Ok(rx) => match rx.recv() {
                Ok(reply) => reply,
                Err(_) => Err(ReplayError::Lost),
            },
            Err(err) => Err(err),
        };
        replies.push((id, outcome));
    }
    ReplayOutcome::from_replies(replies, start.elapsed())
}

/// Outcome of a delta-trace (warm-start session) replay, measured at
/// the client: how much of the update stream was actually served warm,
/// and how often the client had to fall back to a cold re-solve of its
/// edited graph because the session was evicted.
#[derive(Debug, Clone)]
pub struct SessionReplayOutcome {
    pub sent: usize,
    /// Session opens that succeeded (cold solves retaining state).
    pub opens: usize,
    /// Updates served warm from a retained residual cache.
    pub warm_hits: usize,
    /// Updates answered `SessionEvicted` and re-solved cold from the
    /// trace's materialised edited instance.
    pub cold_fallbacks: usize,
    pub rejected: usize,
    pub failed: usize,
    /// Reply channels dropped without an answer — must stay zero.
    pub lost: usize,
    pub wall_seconds: f64,
    /// Latencies over successful replies (warm and cold alike).
    pub overall: Option<Summary>,
    /// Per-request outcomes in trace order; a cold fallback's reply
    /// replaces the evicted one at the same trace id.
    pub replies: Vec<(usize, Result<SolveReply, ReplayError>)>,
}

impl SessionReplayOutcome {
    /// warm_hits / updates-that-got-an-answer — the headline E13 rate.
    pub fn warm_rate(&self) -> f64 {
        let answered = self.warm_hits + self.cold_fallbacks;
        if answered == 0 {
            0.0
        } else {
            self.warm_hits as f64 / answered as f64
        }
    }
}

/// Replay a delta trace through the pool's session API.
///
/// Sequential by session-dependency: an update cannot be submitted
/// before its open's reply carries the service-assigned session id.
/// Requests still honour arrival offsets when the trace has them.  An
/// update answered [`ReplayError::SessionEvicted`] falls back to a cold
/// solve of the trace's materialised edited instance — the degraded
/// mode the eviction reply is designed for — and the session id is
/// re-learned if the fallback reopened it.
pub fn replay_sessions(pool: &SolverPool, trace: &DeltaTrace) -> SessionReplayOutcome {
    let start = Timer::start();
    // Logical trace session → service session id (from the open reply).
    let mut session_ids: Vec<Option<u64>> = Vec::new();
    let mut opens = 0usize;
    let mut warm_hits = 0usize;
    let mut cold_fallbacks = 0usize;
    let mut rejected = 0usize;
    let mut failed = 0usize;
    let mut lost = 0usize;
    let mut latencies = Vec::new();
    let mut replies = Vec::with_capacity(trace.len());
    for req in &trace.requests {
        let now = start.elapsed();
        if req.arrival > now {
            std::thread::sleep(Duration::from_secs_f64(req.arrival - now));
        }
        let deadline = req.deadline.map(Duration::from_secs_f64);
        if session_ids.len() <= req.session {
            session_ids.resize(req.session + 1, None);
        }
        let slot = match &req.kind {
            DeltaKind::Open(net) => {
                pool.try_submit_session(ProblemInstance::Grid(net.clone()), deadline)
            }
            DeltaKind::Update(deltas) => match session_ids[req.session] {
                // No live session (open failed or was rejected): go
                // straight to the cold fallback below via an
                // immediately-evicted receiver.
                None => {
                    let (tx, rx) = std::sync::mpsc::channel();
                    let _ = tx.send(Err(ReplayError::SessionEvicted));
                    Ok(rx)
                }
                Some(sid) => pool.try_submit_update(sid, deltas.clone(), deadline),
            },
        };
        let mut outcome = match slot {
            Ok(rx) => rx.recv().unwrap_or(Err(ReplayError::Lost)),
            Err(reason) => Err(ReplayError::Rejected(reason)),
        };
        if matches!(outcome, Err(ReplayError::SessionEvicted)) {
            // Cold fallback: re-open a session on the edited instance
            // so later updates of this session can go warm again.
            session_ids[req.session] = None;
            let edited = trace.edited[req.id].clone();
            outcome = match pool.try_submit_session(ProblemInstance::Grid(edited), deadline) {
                Ok(rx) => rx.recv().unwrap_or(Err(ReplayError::Lost)),
                Err(reason) => Err(ReplayError::Rejected(reason)),
            };
            if outcome.is_ok() {
                cold_fallbacks += 1;
            }
        }
        match &outcome {
            Ok(reply) => {
                latencies.push(reply.latency);
                if reply.warm {
                    warm_hits += 1;
                } else {
                    opens += 1;
                }
                session_ids[req.session] = reply.session;
            }
            Err(ReplayError::Rejected(_)) => rejected += 1,
            Err(ReplayError::Failed { .. }) => {
                // The pool drops a session on any failed update.
                session_ids[req.session] = None;
                failed += 1;
            }
            Err(ReplayError::Lost) => {
                failed += 1;
                lost += 1;
            }
            Err(ReplayError::SessionEvicted) => {
                // Fallback above also missed (rejected/failed): count
                // it once here as a failure.
                failed += 1;
            }
        }
        replies.push((req.id, outcome));
    }
    SessionReplayOutcome {
        sent: trace.len(),
        opens,
        warm_hits,
        cold_fallbacks,
        rejected,
        failed,
        lost,
        wall_seconds: start.elapsed(),
        overall: Summary::of(&latencies),
        replies,
    }
}

/// The pre-pool deployment shape, kept as the benchmark baseline: one
/// fresh OS thread *and one fresh backend state* per request (no
/// worker reuse, no scratch/artifact caching, no admission control).
pub fn replay_spawn_baseline(
    trace: &MixedTrace,
    shard: &ShardConfig,
    router: &RouterConfig,
) -> ReplayOutcome {
    let start = Timer::start();
    let mut handles = Vec::with_capacity(trace.len());
    for req in &trace.requests {
        let instance = req.instance.clone();
        let class = shard.classify(instance.work_units());
        let rcfg = router.clone();
        let id = req.id;
        handles.push((
            id,
            std::thread::spawn(move || {
                let t = Timer::start();
                let mut backends = WorkerBackends::new(rcfg, None);
                let solved = backends.solve(class, &instance, &CancelToken::new());
                let latency = t.elapsed();
                solved
                    .map(|served| SolveReply {
                        id: id as u64,
                        class,
                        worker: usize::MAX,
                        backend: served.backend,
                        latency,
                        queue_delay: 0.0,
                        retries: served.retries,
                        breaker_skips: served.breaker_skips,
                        session: None,
                        warm: false,
                        phases: None,
                        outcome: served.outcome,
                    })
                    .map_err(|fail| ReplayError::Failed {
                        message: fail.error,
                        retries: fail.retries,
                    })
            }),
        ));
    }
    let mut replies = Vec::with_capacity(handles.len());
    for (id, handle) in handles {
        let outcome = match handle.join() {
            Ok(r) => r,
            Err(_) => Err(ReplayError::Failed {
                message: "solver panicked".to_string(),
                retries: 0,
            }),
        };
        replies.push((id, outcome));
    }
    ReplayOutcome::from_replies(replies, start.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;
    use crate::assignment::AssignmentSolver;
    use crate::util::Rng;
    use crate::workloads::{MixedTraceConfig, TraceConfig};

    fn tiny_trace(seed: u64) -> MixedTrace {
        let mut rng = Rng::seeded(seed);
        MixedTrace::generate(
            &mut rng,
            &MixedTraceConfig {
                assign: TraceConfig {
                    requests: 5,
                    n: 8,
                    arrival_gap: 0.0,
                    ..Default::default()
                },
                grid_requests: 2,
                grid_size: 6,
                grid_arrival_gap: 0.0,
                large_every: 0,
                ..Default::default()
            },
        )
    }

    #[test]
    fn reject_breakdown_counts_by_reason() {
        use super::super::{PoolConfig, SolverPool};
        // Admission cap below the grid size: every grid request is
        // rejected as too-large, every matching is served.
        let mut cfg = PoolConfig {
            workers: 1,
            ..Default::default()
        };
        cfg.shard.max_units = 100; // n=8 matchings (64 units) admit; 12² grids (144) do not
        let mut rng = Rng::seeded(6);
        let trace = MixedTrace::generate(
            &mut rng,
            &MixedTraceConfig {
                assign: TraceConfig {
                    requests: 3,
                    n: 8,
                    arrival_gap: 0.0,
                    ..Default::default()
                },
                grid_requests: 2,
                grid_size: 12, // 144 units > max_units = 100
                grid_arrival_gap: 0.0,
                large_every: 0,
                ..Default::default()
            },
        );
        let pool = SolverPool::start(cfg);
        let out = replay(&pool, &trace, false);
        drop(pool.shutdown());
        assert_eq!(out.ok, 3);
        assert_eq!(out.rejected, 2);
        assert_eq!(out.reject_reasons, vec![("too-large", 2)]);
    }

    #[test]
    fn spawn_baseline_solves_the_whole_trace() {
        let trace = tiny_trace(5);
        let out = replay_spawn_baseline(&trace, &ShardConfig::default(), &RouterConfig::default());
        assert_eq!(out.sent, 7);
        assert_eq!(out.ok, 7);
        assert_eq!(out.rejected + out.failed, 0);
        assert!(out.overall.is_some());
        // Every assignment answer is optimal.
        for (id, reply) in &out.replies {
            if let (Ok(reply), ProblemInstance::Assignment(inst)) =
                (reply, &trace.requests[*id].instance)
            {
                if let Some(weight) = reply.outcome.weight() {
                    assert_eq!(weight, Hungarian.solve(inst).unwrap().weight);
                }
            }
        }
    }
}
