//! Measurement-driven routing: the shared telemetry sink behind the
//! adaptive router.
//!
//! Every solve records its backend latency into a per-(family ×
//! size-class × backend) [`Ewma`](crate::util::stats::Ewma) held in one
//! [`TelemetrySink`] shared by all solver workers.  Route decisions in
//! adaptive mode go through [`TelemetrySink::choose`]:
//!
//! 1. **Cold start** — any candidate backend with no recorded sample
//!    yet is taken first (in registration order), so every engine gets
//!    measured before the sink claims to know a winner.
//! 2. **Probe** — every `probe_every`-th decision for a (family,
//!    class) pair routes round-robin across the candidates instead of
//!    to the winner.  This is a deterministic ε-greedy (ε =
//!    1/probe_every): stale EWMAs keep getting refreshed, so a backend
//!    that regressed — or one that got faster as instances drifted —
//!    is re-discovered without a wall clock or RNG in the decision
//!    path (decisions are reproducible under a single worker).
//! 3. **Steady state** — route to the candidate with the lowest
//!    latency EWMA.
//!
//! Saturation spill (Large grid solves → `fifo-lockfree` when the
//! shared wave pool's queue is backed up) is decided in the router,
//! which consults the pool depth; the sink only counts the spills so
//! reports can show them.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::util::stats::Ewma;

use super::router::Family;
use super::shard::SizeClass;

/// Smoothing factor for the per-backend latency EWMAs.  0.3 weights
/// roughly the last half-dozen solves; fast enough that a backend that
/// turns slow is demoted within a few probes, smooth enough that one
/// noisy sample does not flip the winner.
pub const EWMA_ALPHA: f64 = 0.3;

/// How the service picks a backend per request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingMode {
    /// The PR 3 behaviour: a fixed per-size-class table
    /// ([`RouterConfig::assign`](super::RouterConfig::assign) /
    /// [`grid`](super::RouterConfig::grid)), bit-exact with the
    /// pre-adaptive service.
    #[default]
    Static,
    /// Measurement-driven: latency EWMAs + ε-greedy probing + winner
    /// routing, with saturation spill for Large grids.
    Adaptive,
}

impl RoutingMode {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "static" => RoutingMode::Static,
            "adaptive" => RoutingMode::Adaptive,
            other => bail!("unknown routing mode {other:?} (expected static or adaptive)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutingMode::Static => "static",
            RoutingMode::Adaptive => "adaptive",
        }
    }
}

/// One row of the routing telemetry: how often a backend served a
/// (family, class) pair and at what smoothed latency.
#[derive(Debug, Clone)]
pub struct RouteStat {
    pub family: Family,
    pub class: SizeClass,
    pub backend: &'static str,
    /// Requests this backend served for the pair.
    pub count: u64,
    /// Latency EWMA in seconds (`None` only for rows that were chosen
    /// but never finished recording, which cannot happen via `record`).
    pub ewma_seconds: Option<f64>,
}

/// Circuit-breaker lifecycle for one (family, class, backend) triple.
///
/// `Closed` → (threshold consecutive failures) → `Open` → (cooldown
/// *completed requests* for the pair, not wall clock, so tests are
/// deterministic) → `HalfOpen` → one probe decides: success re-closes,
/// failure re-opens with a fresh cooldown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BreakerState {
    Closed,
    /// Routed around until `remaining` completed requests pass.
    Open { remaining: usize },
    /// The next attempt is the probe.
    HalfOpen,
}

#[derive(Debug, Clone)]
struct BreakerEntry {
    consecutive_failures: usize,
    state: BreakerState,
    opened_total: u64,
}

impl Default for BreakerEntry {
    fn default() -> Self {
        Self {
            consecutive_failures: 0,
            state: BreakerState::Closed,
            opened_total: 0,
        }
    }
}

/// One row of the breaker health snapshot surfaced in
/// `PoolReport::breakers` and the CLI.
#[derive(Debug, Clone)]
pub struct BreakerStat {
    pub family: Family,
    pub class: SizeClass,
    pub backend: &'static str,
    /// "closed" / "open" / "half-open".
    pub state: &'static str,
    pub consecutive_failures: usize,
    /// Times this breaker has tripped over the pool's lifetime.
    pub opened_total: u64,
}

impl BreakerStat {
    pub fn is_open(&self) -> bool {
        self.state == "open"
    }
}

/// Mirror a breaker transition into the global metrics registry:
/// `flowmatch_breaker_state{...}` (0 = closed, 1 = open, 2 = half-open)
/// plus `flowmatch_breaker_opened_total{...}` when the transition is a
/// trip.  Transitions are threshold-many failures apart, so the
/// registry lookup here is nowhere near a hot path.
fn publish_breaker_state(
    family: Family,
    class: SizeClass,
    backend: &'static str,
    state: BreakerState,
    tripped: bool,
) {
    let labels = format!(
        "{{family=\"{}\",class=\"{}\",backend=\"{}\"}}",
        family.name(),
        class.name(),
        backend
    );
    let v = match state {
        BreakerState::Closed => 0,
        BreakerState::Open { .. } => 1,
        BreakerState::HalfOpen => 2,
    };
    crate::obs::global()
        .gauge(&format!("flowmatch_breaker_state{labels}"))
        .set(v);
    if tripped {
        crate::log_warn!(
            "circuit breaker opened for {}/{} backend {backend}",
            family.name(),
            class.name()
        );
        crate::obs::global()
            .counter(&format!("flowmatch_breaker_opened_total{labels}"))
            .inc();
    }
}

#[derive(Default)]
struct SinkState {
    /// Keyed by (family index, class index, backend name); BTreeMap so
    /// snapshots iterate in a stable report order.
    routes: BTreeMap<(usize, usize, &'static str), Ewma>,
    /// Decision counters per (family, class) — the probe clock.
    decisions: [[u64; 3]; 2],
    spills: u64,
    /// Circuit breakers, same key shape as `routes`.  Entries only
    /// exist for backends that have failed at least once.
    breakers: BTreeMap<(usize, usize, &'static str), BreakerEntry>,
}

/// The shared measurement sink: one per [`SolverPool`](super::SolverPool),
/// written by every worker after every solve.
pub struct TelemetrySink {
    probe_every: u64,
    /// Consecutive failures that trip a breaker (0 disables breakers).
    breaker_threshold: usize,
    /// Completed requests an open breaker waits before half-open.
    breaker_cooldown: usize,
    state: Mutex<SinkState>,
}

impl TelemetrySink {
    /// `probe_every = N` probes one decision in `N` (ε = 1/N); 0
    /// disables probing entirely (cold-start measurement still runs).
    /// Breakers use the [`RouterConfig`](super::RouterConfig) defaults;
    /// [`TelemetrySink::with_breaker`] sets them explicitly.
    pub fn new(probe_every: usize) -> Self {
        Self::with_breaker(probe_every, 3, 8)
    }

    /// Full constructor: probe cadence plus the breaker trip threshold
    /// (consecutive failures; 0 disables) and cooldown (completed
    /// requests before an open breaker goes half-open).
    pub fn with_breaker(probe_every: usize, threshold: usize, cooldown: usize) -> Self {
        Self {
            probe_every: probe_every as u64,
            breaker_threshold: threshold,
            breaker_cooldown: cooldown.max(1),
            state: Mutex::new(SinkState::default()),
        }
    }

    /// Record one served request's backend latency (seconds spent in
    /// the solve, excluding queue delay).
    pub fn record(&self, family: Family, class: SizeClass, backend: &'static str, secs: f64) {
        let mut st = self.state.lock().unwrap();
        st.routes
            .entry((family.index(), class.index(), backend))
            .or_insert_with(|| Ewma::new(EWMA_ALPHA))
            .record(secs);
    }

    /// Count one saturation spill (router decided it; see module doc).
    pub fn record_spill(&self) {
        self.state.lock().unwrap().spills += 1;
    }

    /// Whether the breaker for this triple admits traffic (`Closed` or
    /// `HalfOpen`; an open breaker is routed around).
    pub fn breaker_allows(&self, family: Family, class: SizeClass, backend: &'static str) -> bool {
        let st = self.state.lock().unwrap();
        match st.breakers.get(&(family.index(), class.index(), backend)) {
            Some(e) => !matches!(e.state, BreakerState::Open { .. }),
            None => true,
        }
    }

    /// Record one failed (errored or panicked) attempt against the
    /// breaker.  `threshold` consecutive failures trip it; a failed
    /// half-open probe re-trips it immediately.
    pub fn record_breaker_failure(&self, family: Family, class: SizeClass, backend: &'static str) {
        let mut st = self.state.lock().unwrap();
        let e = st
            .breakers
            .entry((family.index(), class.index(), backend))
            .or_default();
        e.consecutive_failures += 1;
        if self.breaker_threshold == 0 {
            return; // breakers disabled: count only
        }
        match e.state {
            BreakerState::HalfOpen => {
                // Failed probe: straight back to open, fresh cooldown.
                e.state = BreakerState::Open {
                    remaining: self.breaker_cooldown,
                };
                e.opened_total += 1;
                publish_breaker_state(family, class, backend, e.state, true);
            }
            BreakerState::Closed if e.consecutive_failures >= self.breaker_threshold => {
                e.state = BreakerState::Open {
                    remaining: self.breaker_cooldown,
                };
                e.opened_total += 1;
                publish_breaker_state(family, class, backend, e.state, true);
            }
            // An all-open fallback attempt failed while already open:
            // restart the cooldown so the probe waits for fresh traffic.
            BreakerState::Open { .. } => {
                e.state = BreakerState::Open {
                    remaining: self.breaker_cooldown,
                };
            }
            BreakerState::Closed => {}
        }
    }

    /// Record one successful attempt: closes the breaker (including a
    /// successful half-open probe) and resets the failure streak.
    pub fn record_breaker_success(&self, family: Family, class: SizeClass, backend: &'static str) {
        let mut st = self.state.lock().unwrap();
        if let Some(e) = st
            .breakers
            .get_mut(&(family.index(), class.index(), backend))
        {
            e.consecutive_failures = 0;
            let was_open = e.state != BreakerState::Closed;
            e.state = BreakerState::Closed;
            if was_open {
                publish_breaker_state(family, class, backend, e.state, false);
            }
        }
    }

    /// Advance the open-breaker cooldown clock for one (family, class)
    /// pair: called once per *completed request* (success or failure),
    /// so half-open probing is deterministic under test — no wall time.
    pub fn request_completed(&self, family: Family, class: SizeClass) {
        let mut st = self.state.lock().unwrap();
        for (&(f, c, backend), e) in st.breakers.iter_mut() {
            if f != family.index() || c != class.index() {
                continue;
            }
            if let BreakerState::Open { remaining } = &mut e.state {
                *remaining = remaining.saturating_sub(1);
                if *remaining == 0 {
                    e.state = BreakerState::HalfOpen;
                    publish_breaker_state(family, class, backend, e.state, false);
                }
            }
        }
    }

    /// Stable-ordered copy of every breaker row, for health reports.
    pub fn breaker_snapshot(&self) -> Vec<BreakerStat> {
        let st = self.state.lock().unwrap();
        st.breakers
            .iter()
            .map(|(&(f, c, backend), e)| BreakerStat {
                family: Family::ALL[f],
                class: SizeClass::ALL[c],
                backend,
                state: match e.state {
                    BreakerState::Closed => "closed",
                    BreakerState::Open { .. } => "open",
                    BreakerState::HalfOpen => "half-open",
                },
                consecutive_failures: e.consecutive_failures,
                opened_total: e.opened_total,
            })
            .collect()
    }

    /// How many breakers are currently open.
    pub fn breakers_open(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.breakers
            .values()
            .filter(|e| matches!(e.state, BreakerState::Open { .. }))
            .count()
    }

    /// Pick a backend for a (family, class) request from `candidates`
    /// (must be non-empty, in registration order).
    pub fn choose(
        &self,
        family: Family,
        class: SizeClass,
        candidates: &[&'static str],
    ) -> &'static str {
        assert!(!candidates.is_empty(), "choose with no candidate backends");
        let mut st = self.state.lock().unwrap();
        let tick = st.decisions[family.index()][class.index()];
        st.decisions[family.index()][class.index()] += 1;
        let key = |b: &'static str| (family.index(), class.index(), b);
        // Cold start: measure every candidate once before trusting any EWMA.
        if let Some(&cold) = candidates.iter().find(|&&b| match st.routes.get(&key(b)) {
            None => true,
            Some(e) => e.count() == 0,
        }) {
            return cold;
        }
        // Deterministic ε-greedy probe: cycle the candidates.
        if self.probe_every > 0 && tick % self.probe_every == 0 {
            return candidates[((tick / self.probe_every) % candidates.len() as u64) as usize];
        }
        // Steady state: current EWMA winner.
        candidates
            .iter()
            .copied()
            .min_by(|&a, &b| {
                let ea = st.routes[&key(a)].get().unwrap_or(f64::INFINITY);
                let eb = st.routes[&key(b)].get().unwrap_or(f64::INFINITY);
                ea.partial_cmp(&eb).expect("NaN latency EWMA")
            })
            .expect("non-empty candidates")
    }

    /// Stable-ordered copy of every route row, for reports.
    pub fn snapshot(&self) -> Vec<RouteStat> {
        let st = self.state.lock().unwrap();
        st.routes
            .iter()
            .map(|(&(f, c, backend), ewma)| RouteStat {
                family: Family::ALL[f],
                class: SizeClass::ALL[c],
                backend,
                count: ewma.count(),
                ewma_seconds: ewma.get(),
            })
            .collect()
    }

    pub fn spills(&self) -> usize {
        self.state.lock().unwrap().spills as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: &str = "engine-a";
    const B: &str = "engine-b";

    #[test]
    fn routing_mode_roundtrip() {
        for m in [RoutingMode::Static, RoutingMode::Adaptive] {
            assert_eq!(RoutingMode::parse(m.name()).unwrap(), m);
        }
        assert!(RoutingMode::parse("nope").is_err());
        assert_eq!(RoutingMode::default(), RoutingMode::Static);
    }

    #[test]
    fn cold_start_measures_every_candidate() {
        let sink = TelemetrySink::new(0); // no probing: isolate cold start
        let cands = [A, B];
        assert_eq!(sink.choose(Family::Assignment, SizeClass::Small, &cands), A);
        sink.record(Family::Assignment, SizeClass::Small, A, 0.010);
        assert_eq!(sink.choose(Family::Assignment, SizeClass::Small, &cands), B);
        sink.record(Family::Assignment, SizeClass::Small, B, 0.001);
        // Both measured: winner is the faster one from now on.
        for _ in 0..5 {
            assert_eq!(sink.choose(Family::Assignment, SizeClass::Small, &cands), B);
        }
    }

    /// The headline adaptive behaviour: deterministic injected
    /// latencies flip the EWMA winner.
    #[test]
    fn injected_latencies_flip_the_winner() {
        let sink = TelemetrySink::new(0);
        let cands = [A, B];
        let (fam, class) = (Family::Grid, SizeClass::Large);
        sink.record(fam, class, A, 0.002);
        sink.record(fam, class, B, 0.010);
        assert_eq!(sink.choose(fam, class, &cands), A, "A starts as winner");
        // A regresses hard; within a few samples its EWMA crosses B's.
        for _ in 0..6 {
            sink.record(fam, class, A, 0.050);
        }
        assert_eq!(sink.choose(fam, class, &cands), B, "winner flipped to B");
        // And back: B regresses, A recovers.
        for _ in 0..6 {
            sink.record(fam, class, B, 0.200);
            sink.record(fam, class, A, 0.001);
        }
        assert_eq!(sink.choose(fam, class, &cands), A, "winner flipped back");
    }

    #[test]
    fn probing_revisits_losers_at_the_configured_rate() {
        let sink = TelemetrySink::new(4);
        let cands = [A, B];
        let (fam, class) = (Family::Assignment, SizeClass::Medium);
        sink.record(fam, class, A, 0.001);
        sink.record(fam, class, B, 0.100);
        let picks: Vec<&str> = (0..16).map(|_| sink.choose(fam, class, &cands)).collect();
        let probes_to_b = picks.iter().filter(|p| **p == B).count();
        // Ticks 0,4,8,12 probe round-robin (A,B,A,B) → exactly 2 hit B.
        assert_eq!(probes_to_b, 2, "picks: {picks:?}");
        // Everything that wasn't a probe went to the winner.
        assert_eq!(picks.iter().filter(|p| **p == A).count(), 14);
    }

    #[test]
    fn per_pair_state_is_independent() {
        let sink = TelemetrySink::new(0);
        sink.record(Family::Grid, SizeClass::Small, A, 0.001);
        sink.record(Family::Grid, SizeClass::Large, B, 0.001);
        sink.record(Family::Grid, SizeClass::Large, A, 0.050);
        assert_eq!(sink.choose(Family::Grid, SizeClass::Large, &[A, B]), B);
        // Small never saw B: cold start takes it there.
        assert_eq!(sink.choose(Family::Grid, SizeClass::Small, &[A, B]), B);
    }

    /// The full breaker lifecycle: trip on consecutive failures, cool
    /// down on *completed requests* (no wall clock), half-open probe,
    /// close on success.
    #[test]
    fn breaker_trips_cools_down_and_recovers() {
        let sink = TelemetrySink::with_breaker(0, 2, 3);
        let (fam, class) = (Family::Grid, SizeClass::Medium);
        assert!(sink.breaker_allows(fam, class, A));
        // One failure: still closed (threshold 2).
        sink.record_breaker_failure(fam, class, A);
        assert!(sink.breaker_allows(fam, class, A));
        // Second consecutive failure: open.
        sink.record_breaker_failure(fam, class, A);
        assert!(!sink.breaker_allows(fam, class, A));
        assert_eq!(sink.breakers_open(), 1);
        let snap = sink.breaker_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!((snap[0].backend, snap[0].state), (A, "open"));
        assert_eq!(snap[0].opened_total, 1);
        // Cooldown = 3 completed requests for the pair; requests in a
        // *different* pair do not advance this breaker's clock.
        sink.request_completed(Family::Assignment, SizeClass::Small);
        sink.request_completed(fam, class);
        sink.request_completed(fam, class);
        assert!(!sink.breaker_allows(fam, class, A), "2 of 3 ticks passed");
        sink.request_completed(fam, class);
        assert!(sink.breaker_allows(fam, class, A), "half-open admits a probe");
        assert_eq!(sink.breaker_snapshot()[0].state, "half-open");
        // Successful probe: closed, streak reset.
        sink.record_breaker_success(fam, class, A);
        assert_eq!(sink.breaker_snapshot()[0].state, "closed");
        assert_eq!(sink.breaker_snapshot()[0].consecutive_failures, 0);
        assert_eq!(sink.breakers_open(), 0);
    }

    /// A failed half-open probe re-opens the breaker immediately with a
    /// fresh cooldown (no threshold-many failures needed the 2nd time).
    #[test]
    fn failed_half_open_probe_reopens() {
        let sink = TelemetrySink::with_breaker(0, 2, 2);
        let (fam, class) = (Family::Assignment, SizeClass::Small);
        sink.record_breaker_failure(fam, class, A);
        sink.record_breaker_failure(fam, class, A);
        sink.request_completed(fam, class);
        sink.request_completed(fam, class);
        assert!(sink.breaker_allows(fam, class, A), "half-open");
        sink.record_breaker_failure(fam, class, A);
        assert!(!sink.breaker_allows(fam, class, A), "probe failed: open again");
        assert_eq!(sink.breaker_snapshot()[0].opened_total, 2);
    }

    /// Intervening successes reset the consecutive-failure streak, and
    /// threshold 0 disables tripping entirely.
    #[test]
    fn success_resets_streak_and_zero_threshold_disables() {
        let sink = TelemetrySink::with_breaker(0, 2, 2);
        let (fam, class) = (Family::Grid, SizeClass::Large);
        sink.record_breaker_failure(fam, class, A);
        sink.record_breaker_success(fam, class, A);
        sink.record_breaker_failure(fam, class, A);
        assert!(sink.breaker_allows(fam, class, A), "streak never reached 2");

        let off = TelemetrySink::with_breaker(0, 0, 2);
        for _ in 0..10 {
            off.record_breaker_failure(fam, class, A);
        }
        assert!(off.breaker_allows(fam, class, A), "threshold 0 never trips");
        assert_eq!(off.breaker_snapshot()[0].consecutive_failures, 10);
    }

    #[test]
    fn snapshot_reports_counts_and_ewmas() {
        let sink = TelemetrySink::new(0);
        sink.record(Family::Assignment, SizeClass::Small, A, 0.004);
        sink.record(Family::Assignment, SizeClass::Small, A, 0.004);
        sink.record_spill();
        let snap = sink.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].backend, A);
        assert_eq!(snap[0].count, 2);
        assert!((snap[0].ewma_seconds.unwrap() - 0.004).abs() < 1e-12);
        assert_eq!(sink.spills(), 1);
    }
}
