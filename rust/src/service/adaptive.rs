//! Measurement-driven routing: the shared telemetry sink behind the
//! adaptive router.
//!
//! Every solve records its backend latency into a per-(family ×
//! size-class × backend) [`Ewma`](crate::util::stats::Ewma) held in one
//! [`TelemetrySink`] shared by all solver workers.  Route decisions in
//! adaptive mode go through [`TelemetrySink::choose`]:
//!
//! 1. **Cold start** — any candidate backend with no recorded sample
//!    yet is taken first (in registration order), so every engine gets
//!    measured before the sink claims to know a winner.
//! 2. **Probe** — every `probe_every`-th decision for a (family,
//!    class) pair routes round-robin across the candidates instead of
//!    to the winner.  This is a deterministic ε-greedy (ε =
//!    1/probe_every): stale EWMAs keep getting refreshed, so a backend
//!    that regressed — or one that got faster as instances drifted —
//!    is re-discovered without a wall clock or RNG in the decision
//!    path (decisions are reproducible under a single worker).
//! 3. **Steady state** — route to the candidate with the lowest
//!    latency EWMA.
//!
//! Saturation spill (Large grid solves → `fifo-lockfree` when the
//! shared wave pool's queue is backed up) is decided in the router,
//! which consults the pool depth; the sink only counts the spills so
//! reports can show them.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::util::stats::Ewma;

use super::router::Family;
use super::shard::SizeClass;

/// Smoothing factor for the per-backend latency EWMAs.  0.3 weights
/// roughly the last half-dozen solves; fast enough that a backend that
/// turns slow is demoted within a few probes, smooth enough that one
/// noisy sample does not flip the winner.
pub const EWMA_ALPHA: f64 = 0.3;

/// How the service picks a backend per request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingMode {
    /// The PR 3 behaviour: a fixed per-size-class table
    /// ([`RouterConfig::assign`](super::RouterConfig::assign) /
    /// [`grid`](super::RouterConfig::grid)), bit-exact with the
    /// pre-adaptive service.
    #[default]
    Static,
    /// Measurement-driven: latency EWMAs + ε-greedy probing + winner
    /// routing, with saturation spill for Large grids.
    Adaptive,
}

impl RoutingMode {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "static" => RoutingMode::Static,
            "adaptive" => RoutingMode::Adaptive,
            other => bail!("unknown routing mode {other:?} (expected static or adaptive)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutingMode::Static => "static",
            RoutingMode::Adaptive => "adaptive",
        }
    }
}

/// One row of the routing telemetry: how often a backend served a
/// (family, class) pair and at what smoothed latency.
#[derive(Debug, Clone)]
pub struct RouteStat {
    pub family: Family,
    pub class: SizeClass,
    pub backend: &'static str,
    /// Requests this backend served for the pair.
    pub count: u64,
    /// Latency EWMA in seconds (`None` only for rows that were chosen
    /// but never finished recording, which cannot happen via `record`).
    pub ewma_seconds: Option<f64>,
}

#[derive(Default)]
struct SinkState {
    /// Keyed by (family index, class index, backend name); BTreeMap so
    /// snapshots iterate in a stable report order.
    routes: BTreeMap<(usize, usize, &'static str), Ewma>,
    /// Decision counters per (family, class) — the probe clock.
    decisions: [[u64; 3]; 2],
    spills: u64,
}

/// The shared measurement sink: one per [`SolverPool`](super::SolverPool),
/// written by every worker after every solve.
pub struct TelemetrySink {
    probe_every: u64,
    state: Mutex<SinkState>,
}

impl TelemetrySink {
    /// `probe_every = N` probes one decision in `N` (ε = 1/N); 0
    /// disables probing entirely (cold-start measurement still runs).
    pub fn new(probe_every: usize) -> Self {
        Self {
            probe_every: probe_every as u64,
            state: Mutex::new(SinkState::default()),
        }
    }

    /// Record one served request's backend latency (seconds spent in
    /// the solve, excluding queue delay).
    pub fn record(&self, family: Family, class: SizeClass, backend: &'static str, secs: f64) {
        let mut st = self.state.lock().unwrap();
        st.routes
            .entry((family.index(), class.index(), backend))
            .or_insert_with(|| Ewma::new(EWMA_ALPHA))
            .record(secs);
    }

    /// Count one saturation spill (router decided it; see module doc).
    pub fn record_spill(&self) {
        self.state.lock().unwrap().spills += 1;
    }

    /// Pick a backend for a (family, class) request from `candidates`
    /// (must be non-empty, in registration order).
    pub fn choose(
        &self,
        family: Family,
        class: SizeClass,
        candidates: &[&'static str],
    ) -> &'static str {
        assert!(!candidates.is_empty(), "choose with no candidate backends");
        let mut st = self.state.lock().unwrap();
        let tick = st.decisions[family.index()][class.index()];
        st.decisions[family.index()][class.index()] += 1;
        let key = |b: &'static str| (family.index(), class.index(), b);
        // Cold start: measure every candidate once before trusting any EWMA.
        if let Some(&cold) = candidates.iter().find(|&&b| match st.routes.get(&key(b)) {
            None => true,
            Some(e) => e.count() == 0,
        }) {
            return cold;
        }
        // Deterministic ε-greedy probe: cycle the candidates.
        if self.probe_every > 0 && tick % self.probe_every == 0 {
            return candidates[((tick / self.probe_every) % candidates.len() as u64) as usize];
        }
        // Steady state: current EWMA winner.
        candidates
            .iter()
            .copied()
            .min_by(|&a, &b| {
                let ea = st.routes[&key(a)].get().unwrap_or(f64::INFINITY);
                let eb = st.routes[&key(b)].get().unwrap_or(f64::INFINITY);
                ea.partial_cmp(&eb).expect("NaN latency EWMA")
            })
            .expect("non-empty candidates")
    }

    /// Stable-ordered copy of every route row, for reports.
    pub fn snapshot(&self) -> Vec<RouteStat> {
        let st = self.state.lock().unwrap();
        st.routes
            .iter()
            .map(|(&(f, c, backend), ewma)| RouteStat {
                family: Family::ALL[f],
                class: SizeClass::ALL[c],
                backend,
                count: ewma.count(),
                ewma_seconds: ewma.get(),
            })
            .collect()
    }

    pub fn spills(&self) -> usize {
        self.state.lock().unwrap().spills as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: &str = "engine-a";
    const B: &str = "engine-b";

    #[test]
    fn routing_mode_roundtrip() {
        for m in [RoutingMode::Static, RoutingMode::Adaptive] {
            assert_eq!(RoutingMode::parse(m.name()).unwrap(), m);
        }
        assert!(RoutingMode::parse("nope").is_err());
        assert_eq!(RoutingMode::default(), RoutingMode::Static);
    }

    #[test]
    fn cold_start_measures_every_candidate() {
        let sink = TelemetrySink::new(0); // no probing: isolate cold start
        let cands = [A, B];
        assert_eq!(sink.choose(Family::Assignment, SizeClass::Small, &cands), A);
        sink.record(Family::Assignment, SizeClass::Small, A, 0.010);
        assert_eq!(sink.choose(Family::Assignment, SizeClass::Small, &cands), B);
        sink.record(Family::Assignment, SizeClass::Small, B, 0.001);
        // Both measured: winner is the faster one from now on.
        for _ in 0..5 {
            assert_eq!(sink.choose(Family::Assignment, SizeClass::Small, &cands), B);
        }
    }

    /// The headline adaptive behaviour: deterministic injected
    /// latencies flip the EWMA winner.
    #[test]
    fn injected_latencies_flip_the_winner() {
        let sink = TelemetrySink::new(0);
        let cands = [A, B];
        let (fam, class) = (Family::Grid, SizeClass::Large);
        sink.record(fam, class, A, 0.002);
        sink.record(fam, class, B, 0.010);
        assert_eq!(sink.choose(fam, class, &cands), A, "A starts as winner");
        // A regresses hard; within a few samples its EWMA crosses B's.
        for _ in 0..6 {
            sink.record(fam, class, A, 0.050);
        }
        assert_eq!(sink.choose(fam, class, &cands), B, "winner flipped to B");
        // And back: B regresses, A recovers.
        for _ in 0..6 {
            sink.record(fam, class, B, 0.200);
            sink.record(fam, class, A, 0.001);
        }
        assert_eq!(sink.choose(fam, class, &cands), A, "winner flipped back");
    }

    #[test]
    fn probing_revisits_losers_at_the_configured_rate() {
        let sink = TelemetrySink::new(4);
        let cands = [A, B];
        let (fam, class) = (Family::Assignment, SizeClass::Medium);
        sink.record(fam, class, A, 0.001);
        sink.record(fam, class, B, 0.100);
        let picks: Vec<&str> = (0..16).map(|_| sink.choose(fam, class, &cands)).collect();
        let probes_to_b = picks.iter().filter(|p| **p == B).count();
        // Ticks 0,4,8,12 probe round-robin (A,B,A,B) → exactly 2 hit B.
        assert_eq!(probes_to_b, 2, "picks: {picks:?}");
        // Everything that wasn't a probe went to the winner.
        assert_eq!(picks.iter().filter(|p| **p == A).count(), 14);
    }

    #[test]
    fn per_pair_state_is_independent() {
        let sink = TelemetrySink::new(0);
        sink.record(Family::Grid, SizeClass::Small, A, 0.001);
        sink.record(Family::Grid, SizeClass::Large, B, 0.001);
        sink.record(Family::Grid, SizeClass::Large, A, 0.050);
        assert_eq!(sink.choose(Family::Grid, SizeClass::Large, &[A, B]), B);
        // Small never saw B: cold start takes it there.
        assert_eq!(sink.choose(Family::Grid, SizeClass::Small, &[A, B]), B);
    }

    #[test]
    fn snapshot_reports_counts_and_ewmas() {
        let sink = TelemetrySink::new(0);
        sink.record(Family::Assignment, SizeClass::Small, A, 0.004);
        sink.record(Family::Assignment, SizeClass::Small, A, 0.004);
        sink.record_spill();
        let snap = sink.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].backend, A);
        assert_eq!(snap[0].count, 2);
        assert!((snap[0].ewma_seconds.unwrap() - 0.004).abs() < 1e-12);
        assert_eq!(sink.spills(), 1);
    }
}
