//! Deterministic fault injection for the service stack.
//!
//! A [`FaultPlan`] describes *when* a targeted backend misbehaves —
//! panic every k-th solve, fail every m-th, sleep, or corrupt the
//! result — as pure functions of a shared solve counter, so a chaos run
//! with a fixed seed replays identically: no RNG, no wall clock in the
//! decision path.  [`FaultyBackend`] wraps the real backend inside the
//! registry (see `BackendRegistry::instantiate`), so injected faults
//! exercise exactly the production retry / breaker / respawn paths.
//!
//! [`backoff_delay`] is the retry schedule used by the router: plain
//! deterministic exponential backoff, unit-tested here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::CancelToken;
use crate::workloads::ProblemInstance;

use super::router::{Backend, Family};
use super::SolveOutcome;

/// Deterministic exponential backoff before retry number `attempt`
/// (1-based): `base_ms`, `2*base_ms`, `4*base_ms`, ...  The shift is
/// capped so the delay never overflows; `base_ms = 0` disables waiting.
pub fn backoff_delay(base_ms: u64, attempt: u32) -> Duration {
    if base_ms == 0 || attempt == 0 {
        return Duration::ZERO;
    }
    let shift = (attempt - 1).min(10);
    Duration::from_millis(base_ms.saturating_mul(1u64 << shift))
}

/// A seeded, deterministic misbehaviour schedule for one backend.
///
/// The counters are shared (`Arc`) across every clone of the plan, so
/// all workers wrapping the same target draw from one global solve
/// sequence — which solve panics does not depend on how requests were
/// spread over workers.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Registry name of the backend to wrap (e.g. "native-par").
    pub target: String,
    /// Panic on every `panic_every`-th solve (0 = never).
    pub panic_every: u64,
    /// Return an error on every `fail_every`-th solve (0 = never).
    pub fail_every: u64,
    /// Sleep `delay_ms` on every `delay_every`-th solve (0 = never).
    pub delay_every: u64,
    pub delay_ms: u64,
    /// Corrupt the result (weight/flow + 1) on every `wrong_every`-th
    /// solve (0 = never) — for oracle-detection tests only; chaos mode
    /// never sets it, so successful chaos solves stay bit-exact.
    pub wrong_every: u64,
    counter: Arc<AtomicU64>,
    injected: Arc<AtomicU64>,
}

impl FaultPlan {
    /// A plan that never misbehaves; combine with the `with_*` builders.
    pub fn new(target: impl Into<String>) -> Self {
        Self {
            target: target.into(),
            panic_every: 0,
            fail_every: 0,
            delay_every: 0,
            delay_ms: 0,
            wrong_every: 0,
            counter: Arc::new(AtomicU64::new(0)),
            injected: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn with_panic_every(mut self, k: u64) -> Self {
        self.panic_every = k;
        self
    }

    pub fn with_fail_every(mut self, k: u64) -> Self {
        self.fail_every = k;
        self
    }

    pub fn with_delay_every(mut self, k: u64, ms: u64) -> Self {
        self.delay_every = k;
        self.delay_ms = ms;
        self
    }

    pub fn with_wrong_every(mut self, k: u64) -> Self {
        self.wrong_every = k;
        self
    }

    /// The `loadgen --chaos <seed>` schedule: panics plus plain errors
    /// on the parallel grid backend, never corrupted results (so every
    /// success stays oracle-exact).  The cadences are derived from the
    /// seed but always ≥ 2, so some solves also succeed and the
    /// breaker/telemetry see a mixed diet.
    pub fn chaos(seed: u64) -> Self {
        Self::new("native-par")
            .with_panic_every(2 + seed % 3)
            .with_fail_every(7 + (seed >> 2) % 4)
    }

    /// Total faults injected so far (panics + errors + delays + wrongs).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    /// Solves the wrapped backend has been offered so far.
    pub fn solves(&self) -> u64 {
        self.counter.load(Ordering::SeqCst)
    }
}

/// Wraps a real backend and misbehaves per its [`FaultPlan`].  Keeps
/// the inner backend's name, so routing tables, telemetry, and breakers
/// all attribute the faults to the real engine — the whole point.
pub struct FaultyBackend {
    inner: Box<dyn Backend>,
    plan: FaultPlan,
}

impl FaultyBackend {
    pub fn wrap(inner: Box<dyn Backend>, plan: FaultPlan) -> Self {
        Self { inner, plan }
    }
}

impl Backend for FaultyBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn family(&self) -> Family {
        self.inner.family()
    }

    fn accepts(&self, instance: &ProblemInstance) -> bool {
        self.inner.accepts(instance)
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        // 1-based global solve number: deterministic across workers.
        let k = self.plan.counter.fetch_add(1, Ordering::SeqCst) + 1;
        let hit = |every: u64| every > 0 && k % every == 0;
        if hit(self.plan.delay_every) {
            self.plan.injected.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(self.plan.delay_ms));
        }
        if hit(self.plan.panic_every) {
            self.plan.injected.fetch_add(1, Ordering::SeqCst);
            panic!(
                "fault injection: backend {} panicked on solve #{k}",
                self.inner.name()
            );
        }
        if hit(self.plan.fail_every) {
            self.plan.injected.fetch_add(1, Ordering::SeqCst);
            bail!(
                "fault injection: backend {} failed on solve #{k}",
                self.inner.name()
            );
        }
        let mut out = self.inner.solve(instance, cancel)?;
        if hit(self.plan.wrong_every) {
            self.plan.injected.fetch_add(1, Ordering::SeqCst);
            match &mut out {
                SolveOutcome::Assignment(r) => r.weight += 1,
                SolveOutcome::Grid(r) => r.flow += 1,
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gridflow::GridSolveReport;
    use crate::util::Rng;
    use crate::workloads::random_grid;

    /// Backoff is a pure function of (base, attempt): the retry
    /// schedule replays identically run to run.
    #[test]
    fn backoff_is_deterministic_and_exponential() {
        assert_eq!(backoff_delay(2, 1), Duration::from_millis(2));
        assert_eq!(backoff_delay(2, 2), Duration::from_millis(4));
        assert_eq!(backoff_delay(2, 3), Duration::from_millis(8));
        assert_eq!(backoff_delay(5, 4), Duration::from_millis(40));
        // Disabled / degenerate inputs.
        assert_eq!(backoff_delay(0, 3), Duration::ZERO);
        assert_eq!(backoff_delay(2, 0), Duration::ZERO);
        // The shift cap keeps huge attempt numbers finite (no overflow).
        assert_eq!(backoff_delay(1, 64), Duration::from_millis(1 << 10));
        // Same inputs, same answer — twice.
        for attempt in 1..8 {
            assert_eq!(backoff_delay(3, attempt), backoff_delay(3, attempt));
        }
    }

    /// A stub backend that always succeeds with a fixed flow.
    struct Steady;

    impl Backend for Steady {
        fn name(&self) -> &'static str {
            "steady"
        }

        fn family(&self) -> Family {
            Family::Grid
        }

        fn solve(&mut self, _: &ProblemInstance, _: &CancelToken) -> Result<SolveOutcome> {
            Ok(SolveOutcome::Grid(GridSolveReport {
                flow: 7,
                ..Default::default()
            }))
        }
    }

    fn grid_instance() -> ProblemInstance {
        let mut rng = Rng::seeded(1);
        ProblemInstance::Grid(random_grid(&mut rng, 4, 4, 5, 0.3, 0.3))
    }

    #[test]
    fn fail_schedule_hits_exact_solves() {
        let plan = FaultPlan::new("steady").with_fail_every(3);
        let mut b = FaultyBackend::wrap(Box::new(Steady), plan.clone());
        let inst = grid_instance();
        let cancel = CancelToken::new();
        let mut outcomes = Vec::new();
        for _ in 0..9 {
            outcomes.push(b.solve(&inst, &cancel).is_ok());
        }
        // Solves 3, 6, 9 fail; everything else succeeds.
        assert_eq!(
            outcomes,
            [true, true, false, true, true, false, true, true, false]
        );
        assert_eq!(plan.injected(), 3);
        assert_eq!(plan.solves(), 9);
    }

    #[test]
    fn panic_schedule_panics_on_the_kth_solve() {
        let plan = FaultPlan::new("steady").with_panic_every(2);
        let mut b = FaultyBackend::wrap(Box::new(Steady), plan);
        let inst = grid_instance();
        let cancel = CancelToken::new();
        assert!(b.solve(&inst, &cancel).is_ok());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.solve(&inst, &cancel);
        }));
        assert!(r.is_err(), "solve #2 must panic");
        assert!(b.solve(&inst, &cancel).is_ok(), "solve #3 succeeds again");
    }

    #[test]
    fn wrong_schedule_corrupts_the_result() {
        let plan = FaultPlan::new("steady").with_wrong_every(1);
        let mut b = FaultyBackend::wrap(Box::new(Steady), plan);
        let out = b.solve(&grid_instance(), &CancelToken::new()).unwrap();
        assert_eq!(out.flow(), Some(8), "flow 7 corrupted to 8");
    }

    #[test]
    fn shared_counters_survive_cloning() {
        // Two wrappers from clones of one plan (two workers) share the
        // schedule: the global 2nd solve fails no matter who runs it.
        let plan = FaultPlan::new("steady").with_fail_every(2);
        let mut w0 = FaultyBackend::wrap(Box::new(Steady), plan.clone());
        let mut w1 = FaultyBackend::wrap(Box::new(Steady), plan.clone());
        let inst = grid_instance();
        let cancel = CancelToken::new();
        assert!(w0.solve(&inst, &cancel).is_ok()); // global #1
        assert!(w1.solve(&inst, &cancel).is_err()); // global #2
        assert_eq!(plan.solves(), 2);
    }

    #[test]
    fn chaos_plan_is_seed_deterministic() {
        let a = FaultPlan::chaos(7);
        let b = FaultPlan::chaos(7);
        assert_eq!(a.target, "native-par");
        assert_eq!((a.panic_every, a.fail_every), (b.panic_every, b.fail_every));
        assert_eq!((a.panic_every, a.fail_every), (3, 8));
        assert_eq!(a.wrong_every, 0, "chaos never corrupts results");
        assert!(a.panic_every >= 2 && a.fail_every >= 2);
    }
}
