//! Backend routing: which solver engine serves a request, chosen per
//! problem family and size class, with per-worker cached state.
//!
//! Assignment requests can go to the exact Hungarian baseline, the
//! sequential cost-scaling engine, the paper's lock-free refine, the
//! dense wave twin, or (when artifacts are discoverable) the PJRT
//! device driver.  Grid max-flow requests can go to the sequential
//! native wave engine, the tiled multi-threaded engine (borrowing the
//! shared [`WorkerPool`](super::pool::WorkerPool) instead of spawning
//! per-wave threads), or Hong's lock-free CSR engine.
//!
//! Everything a backend needs between requests is cached on the worker
//! ([`WorkerBackends`]): executor scratch (active lists, BFS buffers)
//! and the compiled PJRT artifact handle, which is `!Send` and so must
//! live on the worker thread that created it.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::assignment::{self, AssignmentSolver};
use crate::coordinator::PjrtAssignmentDriver;
use crate::graph::GridNetwork;
use crate::gridflow::{
    GridSolveReport, HybridGridSolver, NativeGridExecutor, NativeParGridExecutor,
};
use crate::maxflow::{self, MaxFlowSolver};
use crate::runtime::ArtifactRegistry;
use crate::workloads::ProblemInstance;

use super::pool::WorkerPool;
use super::shard::SizeClass;
use super::SolveOutcome;

/// Native assignment backends (the PJRT driver is layered on top via
/// [`RouterConfig::use_pjrt`], mirroring the hybrid drivers' Auto mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignBackend {
    Hungarian,
    CsaSeq,
    CsaLockfree,
    WaveCsa,
}

impl AssignBackend {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "hungarian" => AssignBackend::Hungarian,
            "csa-seq" => AssignBackend::CsaSeq,
            "csa-lockfree" => AssignBackend::CsaLockfree,
            "csa-wave" => AssignBackend::WaveCsa,
            other => bail!(
                "unknown assignment backend {other:?} \
                 (expected hungarian, csa-seq, csa-lockfree, csa-wave)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AssignBackend::Hungarian => "hungarian",
            AssignBackend::CsaSeq => "csa-seq",
            AssignBackend::CsaLockfree => "csa-lockfree",
            AssignBackend::WaveCsa => "csa-wave",
        }
    }
}

/// Grid max-flow backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridBackend {
    /// Sequential native wave engine.
    Native,
    /// Tiled multi-threaded wave engine on the shared worker pool
    /// (bit-exact with `Native`).
    NativePar,
    /// Hong's lock-free engine over the CSR conversion.
    FifoLockfree,
}

impl GridBackend {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "native" => GridBackend::Native,
            "native-par" => GridBackend::NativePar,
            "fifo-lockfree" => GridBackend::FifoLockfree,
            other => bail!(
                "unknown grid backend {other:?} \
                 (expected native, native-par, fifo-lockfree)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            GridBackend::Native => "native",
            GridBackend::NativePar => "native-par",
            GridBackend::FifoLockfree => "fifo-lockfree",
        }
    }
}

/// Routing table + engine tunables, one copy per worker.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Assignment backend per size class, indexed by [`SizeClass::index`].
    pub assign: [AssignBackend; 3],
    /// Grid backend per size class.
    pub grid: [GridBackend; 3],
    /// Prefer the PJRT driver for assignment instances that fit its
    /// padded size, falling back to the native table on any miss.
    pub use_pjrt: bool,
    /// Size the per-worker PJRT driver is built for.
    pub pjrt_max_n: usize,
    /// Cost-scaling alpha for the CSA engines.
    pub alpha: i64,
    /// Threads of the lock-free CSA refine.
    pub csa_threads: usize,
    /// Waves per host round of the hybrid grid solver.
    pub cycle_waves: usize,
    /// Wave-pool width used by the `native-par` grid backend.
    pub par_threads: usize,
    pub tile_rows: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            assign: [
                AssignBackend::Hungarian,
                AssignBackend::CsaLockfree,
                AssignBackend::CsaLockfree,
            ],
            grid: [GridBackend::Native, GridBackend::NativePar, GridBackend::NativePar],
            use_pjrt: false,
            pjrt_max_n: 64,
            alpha: 10,
            csa_threads: 2,
            cycle_waves: 512,
            par_threads: 4,
            tile_rows: 16,
        }
    }
}

/// Per-worker backend state: cached executors (scratch survives across
/// requests) and the optional PJRT driver.
pub(crate) struct WorkerBackends {
    cfg: RouterConfig,
    pjrt: Option<PjrtAssignmentDriver>,
    seq_exec: NativeGridExecutor,
    par_exec: NativeParGridExecutor,
}

impl WorkerBackends {
    /// Build the worker's caches.  PJRT discovery happens once, here —
    /// not per request; `wave_pool` is the shared persistent pool the
    /// `native-par` backend borrows (None: fall back to per-wave scoped
    /// threads, used by the spawn-baseline loadgen path).
    pub fn new(cfg: RouterConfig, wave_pool: Option<&Arc<WorkerPool>>) -> Self {
        let pjrt = if cfg.use_pjrt {
            ArtifactRegistry::discover()
                .ok()
                .and_then(|reg| PjrtAssignmentDriver::for_size(&reg, cfg.pjrt_max_n).ok())
                .map(|mut d| {
                    d.alpha = cfg.alpha;
                    d
                })
        } else {
            None
        };
        let mut par_exec = NativeParGridExecutor::new(cfg.par_threads, cfg.tile_rows);
        if let Some(pool) = wave_pool {
            par_exec = par_exec.with_pool(Arc::clone(pool));
        }
        Self {
            cfg,
            pjrt,
            seq_exec: NativeGridExecutor::default(),
            par_exec,
        }
    }

    /// Solve one request; returns the outcome plus the backend name
    /// that actually served it.
    pub fn solve(
        &mut self,
        class: SizeClass,
        instance: &ProblemInstance,
    ) -> Result<(SolveOutcome, &'static str)> {
        match instance {
            ProblemInstance::Assignment(inst) => {
                if let Some(driver) = self.pjrt.as_mut() {
                    if inst.n <= driver.padded_n() {
                        let (result, _tel) = driver.solve(inst)?;
                        return Ok((SolveOutcome::Assignment(result), "pjrt"));
                    }
                }
                let backend = self.cfg.assign[class.index()];
                let result = match backend {
                    AssignBackend::Hungarian => assignment::hungarian::Hungarian.solve(inst)?,
                    AssignBackend::CsaSeq => {
                        assignment::csa::SequentialCsa::with_alpha(self.cfg.alpha).solve(inst)?
                    }
                    AssignBackend::CsaLockfree => assignment::csa_lockfree::LockFreeCsa {
                        alpha: self.cfg.alpha,
                        threads: self.cfg.csa_threads,
                    }
                    .solve(inst)?,
                    AssignBackend::WaveCsa => assignment::wave::WaveCsa {
                        alpha: Some(self.cfg.alpha),
                    }
                    .solve(inst)?,
                };
                Ok((SolveOutcome::Assignment(result), backend.name()))
            }
            ProblemInstance::Grid(net) => {
                let backend = self.cfg.grid[class.index()];
                let report = self.solve_grid(backend, net)?;
                Ok((SolveOutcome::Grid(report), backend.name()))
            }
        }
    }

    fn solve_grid(&mut self, backend: GridBackend, net: &GridNetwork) -> Result<GridSolveReport> {
        let solver = HybridGridSolver::with_cycle(self.cfg.cycle_waves);
        match backend {
            GridBackend::Native => solver.solve(net, &mut self.seq_exec),
            GridBackend::NativePar => solver.solve(net, &mut self.par_exec),
            GridBackend::FifoLockfree => {
                let mut g = net.to_flow_network();
                let stats = maxflow::lockfree::LockFree {
                    threads: self.cfg.par_threads.max(1),
                    ..Default::default()
                }
                .solve(&mut g)?;
                Ok(GridSolveReport {
                    flow: stats.value,
                    excess_total: net.excess_total(),
                    host_rounds: stats.rounds,
                    pushes: stats.pushes as i64,
                    relabels: stats.relabels as i64,
                    ..Default::default()
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;
    use crate::maxflow::dinic::Dinic;
    use crate::util::Rng;
    use crate::workloads::{random_grid, uniform_costs};

    #[test]
    fn backend_names_roundtrip() {
        for b in [
            AssignBackend::Hungarian,
            AssignBackend::CsaSeq,
            AssignBackend::CsaLockfree,
            AssignBackend::WaveCsa,
        ] {
            assert_eq!(AssignBackend::parse(b.name()).unwrap(), b);
        }
        for b in [
            GridBackend::Native,
            GridBackend::NativePar,
            GridBackend::FifoLockfree,
        ] {
            assert_eq!(GridBackend::parse(b.name()).unwrap(), b);
        }
        assert!(AssignBackend::parse("nope").is_err());
        assert!(GridBackend::parse("nope").is_err());
    }

    #[test]
    fn routes_by_class_and_solves_optimally() {
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        let mut rng = Rng::seeded(11);
        let inst = uniform_costs(&mut rng, 12, 50);
        let want = Hungarian.solve(&inst).unwrap().weight;
        for class in SizeClass::ALL {
            let (out, name) = backends
                .solve(class, &ProblemInstance::Assignment(inst.clone()))
                .unwrap();
            assert_eq!(out.weight(), Some(want), "class {}", class.name());
            let expected = RouterConfig::default().assign[class.index()].name();
            assert_eq!(name, expected);
        }
    }

    #[test]
    fn every_grid_backend_agrees_with_dinic() {
        let mut rng = Rng::seeded(12);
        let net = random_grid(&mut rng, 7, 7, 9, 0.3, 0.3);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap().value;
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        for b in [
            GridBackend::Native,
            GridBackend::NativePar,
            GridBackend::FifoLockfree,
        ] {
            let report = backends.solve_grid(b, &net).unwrap();
            assert_eq!(report.flow, want, "backend {}", b.name());
        }
    }
}
