//! Backend routing: which solver engine serves a request.
//!
//! Every engine in the tree is wrapped in one [`Backend`] trait object
//! and registered exactly once in [`BackendRegistry::standard`] — that
//! function is the single place a new engine is added.  A registry is
//! instantiated **per worker** ([`WorkerBackends`]): executor scratch
//! (active lists, BFS buffers) survives across requests, and the
//! compiled PJRT artifact handle, which is `!Send`, lives and dies on
//! the worker thread that built it.
//!
//! Two routing modes sit on top (see [`RoutingMode`]):
//!
//! * **static** — the per-size-class tables in [`RouterConfig`]
//!   (`assign` / `grid`), with PJRT preferred for assignment instances
//!   that fit its padded size.  Bit-exact with the PR 3 service.
//! * **adaptive** — measurement-driven: per-(family × class × backend)
//!   latency EWMAs in the shared [`TelemetrySink`], ε-greedy cold-start
//!   probing, route-to-winner steady state, and saturation spill of
//!   Large grid solves to `fifo-lockfree` whenever the shared wave
//!   pool's queue depth is at or above [`RouterConfig::spill_depth`]
//!   (a saturated pool means `native-par`'s tile phases would queue
//!   behind other solves, so Hong's self-threaded CSR engine wins).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::assignment::{self, AssignmentSolver};
use crate::coordinator::PjrtAssignmentDriver;
use crate::graph::GridNetwork;
use crate::gridflow::{
    GridSolveReport, HostRounds, HybridGridSolver, NativeGridExecutor, NativeParGridExecutor,
};
use crate::maxflow::{self, MaxFlowSolver};
use crate::runtime::ArtifactRegistry;
use crate::workloads::ProblemInstance;

use super::adaptive::{RoutingMode, TelemetrySink};
use super::pool::WorkerPool;
use super::shard::SizeClass;
use super::SolveOutcome;

/// The two problem families the service routes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Family {
    Assignment,
    Grid,
}

impl Family {
    pub const ALL: [Family; 2] = [Family::Assignment, Family::Grid];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            Family::Assignment => 0,
            Family::Grid => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Family::Assignment => "assignment",
            Family::Grid => "grid",
        }
    }

    pub fn of(instance: &ProblemInstance) -> Family {
        match instance {
            ProblemInstance::Assignment(_) => Family::Assignment,
            ProblemInstance::Grid(_) => Family::Grid,
        }
    }
}

/// One solver engine behind the service.  Implementations own whatever
/// state they want cached between requests on a worker (executor
/// scratch, device handles); they are built per worker thread and never
/// cross threads, so `!Send` members are fine.
pub trait Backend {
    /// Stable engine name — the routing tables, telemetry, and reports
    /// all key on it.
    fn name(&self) -> &'static str;

    fn family(&self) -> Family;

    /// Whether this backend can serve `instance` (e.g. PJRT only takes
    /// assignment instances that fit its padded size).  Backends are
    /// only offered instances of their own family.
    fn accepts(&self, instance: &ProblemInstance) -> bool {
        let _ = instance;
        true
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome>;
}

fn wrong_family(backend: &'static str, instance: &ProblemInstance) -> anyhow::Error {
    anyhow::anyhow!(
        "backend {backend} cannot serve a {} instance",
        Family::of(instance).name()
    )
}

// ---------------------------------------------------------------------------
// Assignment backends
// ---------------------------------------------------------------------------

struct HungarianBackend;

impl Backend for HungarianBackend {
    fn name(&self) -> &'static str {
        "hungarian"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::hungarian::Hungarian.solve(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct CsaSeqBackend {
    alpha: i64,
}

impl Backend for CsaSeqBackend {
    fn name(&self) -> &'static str {
        "csa-seq"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::csa::SequentialCsa::with_alpha(self.alpha).solve(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct CsaLockfreeBackend {
    alpha: i64,
    threads: usize,
}

impl Backend for CsaLockfreeBackend {
    fn name(&self) -> &'static str {
        "csa-lockfree"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::csa_lockfree::LockFreeCsa {
                    alpha: self.alpha,
                    threads: self.threads,
                }
                .solve(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct WaveCsaBackend {
    alpha: i64,
}

impl Backend for WaveCsaBackend {
    fn name(&self) -> &'static str {
        "csa-wave"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::wave::WaveCsa {
                    alpha: Some(self.alpha),
                }
                .solve(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

/// The PJRT device driver.  The artifact handle is `!Send` (like a CUDA
/// context); it is discovered and compiled once per worker, here.
struct PjrtBackend {
    driver: PjrtAssignmentDriver,
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn accepts(&self, instance: &ProblemInstance) -> bool {
        match instance {
            ProblemInstance::Assignment(inst) => inst.n <= self.driver.padded_n(),
            ProblemInstance::Grid(_) => false,
        }
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Assignment(inst) => {
                let (result, _tel) = self.driver.solve(inst)?;
                Ok(SolveOutcome::Assignment(result))
            }
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

// ---------------------------------------------------------------------------
// Grid backends
// ---------------------------------------------------------------------------

struct NativeGridBackend {
    exec: NativeGridExecutor,
    cycle_waves: usize,
}

impl Backend for NativeGridBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn family(&self) -> Family {
        Family::Grid
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Grid(net) => Ok(SolveOutcome::Grid(
                HybridGridSolver::with_cycle(self.cycle_waves).solve(net, &mut self.exec)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct NativeParGridBackend {
    exec: NativeParGridExecutor,
    cycle_waves: usize,
    /// `Striped` wires the worker's wave pool into the host rounds too
    /// (via `GridExecutor::host_pool`), so Large solves stop
    /// serialising on the between-wave BFS.  Bit-exact with `Seq`.
    host_rounds: HostRounds,
}

impl Backend for NativeParGridBackend {
    fn name(&self) -> &'static str {
        "native-par"
    }

    fn family(&self) -> Family {
        Family::Grid
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Grid(net) => Ok(SolveOutcome::Grid(
                HybridGridSolver::with_cycle(self.cycle_waves)
                    .with_host_rounds(self.host_rounds)
                    .solve(net, &mut self.exec)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

/// Hong's lock-free engine over the CSR conversion.  It spawns its own
/// scoped threads, so it stays fast when the shared wave pool is
/// saturated — which is exactly why the adaptive router spills to it.
struct FifoLockfreeBackend {
    threads: usize,
}

impl FifoLockfreeBackend {
    fn solve_grid(&self, net: &GridNetwork) -> Result<GridSolveReport> {
        let mut g = net.to_flow_network();
        let stats = maxflow::lockfree::LockFree {
            threads: self.threads.max(1),
            ..Default::default()
        }
        .solve(&mut g)?;
        Ok(GridSolveReport {
            flow: stats.value,
            excess_total: net.excess_total(),
            host_rounds: stats.rounds,
            pushes: stats.pushes as i64,
            relabels: stats.relabels as i64,
            ..Default::default()
        })
    }
}

impl Backend for FifoLockfreeBackend {
    fn name(&self) -> &'static str {
        "fifo-lockfree"
    }

    fn family(&self) -> Family {
        Family::Grid
    }

    fn solve(&mut self, instance: &ProblemInstance) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Grid(net) => Ok(SolveOutcome::Grid(self.solve_grid(net)?)),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

type BuildFn = fn(&RouterConfig, Option<&Arc<WorkerPool>>) -> Option<Box<dyn Backend>>;

/// One registered engine: its stable name, family, and per-worker
/// constructor.  The constructor may return `None` for backends that
/// are unavailable in this process (PJRT without artifacts).
pub struct BackendSpec {
    pub name: &'static str,
    pub family: Family,
    build: BuildFn,
}

/// The engine catalogue.  [`BackendRegistry::standard`] is the single
/// registration point: adding an engine there makes it routable,
/// measurable, and reportable everywhere at once.
pub struct BackendRegistry {
    specs: Vec<BackendSpec>,
}

impl BackendRegistry {
    pub fn new() -> Self {
        Self { specs: Vec::new() }
    }

    pub fn register(&mut self, name: &'static str, family: Family, build: BuildFn) {
        assert!(
            self.specs.iter().all(|s| s.name != name),
            "backend {name:?} registered twice"
        );
        self.specs.push(BackendSpec {
            name,
            family,
            build,
        });
    }

    /// Every in-tree engine, registered once.
    pub fn standard() -> Self {
        let mut r = Self::new();
        r.register("hungarian", Family::Assignment, |_, _| {
            Some(Box::new(HungarianBackend))
        });
        r.register("csa-seq", Family::Assignment, |cfg, _| {
            Some(Box::new(CsaSeqBackend { alpha: cfg.alpha }))
        });
        r.register("csa-lockfree", Family::Assignment, |cfg, _| {
            Some(Box::new(CsaLockfreeBackend {
                alpha: cfg.alpha,
                threads: cfg.csa_threads,
            }))
        });
        r.register("csa-wave", Family::Assignment, |cfg, _| {
            Some(Box::new(WaveCsaBackend { alpha: cfg.alpha }))
        });
        // PJRT discovery happens once, here — not per request; absent
        // artifacts simply leave the backend unregistered on the worker.
        r.register("pjrt", Family::Assignment, |cfg, _| {
            if !cfg.use_pjrt {
                return None;
            }
            ArtifactRegistry::discover()
                .ok()
                .and_then(|reg| PjrtAssignmentDriver::for_size(&reg, cfg.pjrt_max_n).ok())
                .map(|mut d| {
                    d.alpha = cfg.alpha;
                    Box::new(PjrtBackend { driver: d }) as Box<dyn Backend>
                })
        });
        r.register("native", Family::Grid, |cfg, _| {
            Some(Box::new(NativeGridBackend {
                exec: NativeGridExecutor::default(),
                cycle_waves: cfg.cycle_waves,
            }))
        });
        r.register("native-par", Family::Grid, |cfg, pool| {
            let mut exec = NativeParGridExecutor::new(cfg.par_threads, cfg.tile_rows);
            if let Some(pool) = pool {
                exec = exec.with_pool(Arc::clone(pool));
            }
            Some(Box::new(NativeParGridBackend {
                exec,
                cycle_waves: cfg.cycle_waves,
                host_rounds: cfg.host_rounds,
            }))
        });
        r.register("fifo-lockfree", Family::Grid, |cfg, _| {
            Some(Box::new(FifoLockfreeBackend {
                threads: cfg.par_threads.max(1),
            }))
        });
        r
    }

    /// Registered names for a family (whether or not they build on a
    /// given worker).
    pub fn names(&self, family: Family) -> Vec<&'static str> {
        self.specs
            .iter()
            .filter(|s| s.family == family)
            .map(|s| s.name)
            .collect()
    }

    /// Build every available backend for one worker, in registration
    /// order.
    fn instantiate(
        &self,
        cfg: &RouterConfig,
        pool: Option<&Arc<WorkerPool>>,
    ) -> Vec<Box<dyn Backend>> {
        self.specs
            .iter()
            .filter_map(|s| (s.build)(cfg, pool))
            .collect()
    }
}

impl Default for BackendRegistry {
    fn default() -> Self {
        Self::standard()
    }
}

// ---------------------------------------------------------------------------
// Static routing tables (config surface, unchanged from PR 3)
// ---------------------------------------------------------------------------

/// Native assignment backends for the static table (the PJRT driver is
/// layered on top via [`RouterConfig::use_pjrt`], mirroring the hybrid
/// drivers' Auto mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignBackend {
    Hungarian,
    CsaSeq,
    CsaLockfree,
    WaveCsa,
}

impl AssignBackend {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "hungarian" => AssignBackend::Hungarian,
            "csa-seq" => AssignBackend::CsaSeq,
            "csa-lockfree" => AssignBackend::CsaLockfree,
            "csa-wave" => AssignBackend::WaveCsa,
            other => bail!(
                "unknown assignment backend {other:?} \
                 (expected hungarian, csa-seq, csa-lockfree, csa-wave)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AssignBackend::Hungarian => "hungarian",
            AssignBackend::CsaSeq => "csa-seq",
            AssignBackend::CsaLockfree => "csa-lockfree",
            AssignBackend::WaveCsa => "csa-wave",
        }
    }
}

/// Grid max-flow backends for the static table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridBackend {
    /// Sequential native wave engine.
    Native,
    /// Tiled multi-threaded wave engine on the shared worker pool
    /// (bit-exact with `Native`).
    NativePar,
    /// Hong's lock-free engine over the CSR conversion.
    FifoLockfree,
}

impl GridBackend {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "native" => GridBackend::Native,
            "native-par" => GridBackend::NativePar,
            "fifo-lockfree" => GridBackend::FifoLockfree,
            other => bail!(
                "unknown grid backend {other:?} \
                 (expected native, native-par, fifo-lockfree)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            GridBackend::Native => "native",
            GridBackend::NativePar => "native-par",
            GridBackend::FifoLockfree => "fifo-lockfree",
        }
    }
}

/// Routing tables + engine tunables, one copy per worker.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Static assignment backend per size class, indexed by
    /// [`SizeClass::index`].  Ignored in adaptive mode.
    pub assign: [AssignBackend; 3],
    /// Static grid backend per size class.  Ignored in adaptive mode.
    pub grid: [GridBackend; 3],
    /// Prefer the PJRT driver for assignment instances that fit its
    /// padded size, falling back to the native table on any miss.
    pub use_pjrt: bool,
    /// Size the per-worker PJRT driver is built for.
    pub pjrt_max_n: usize,
    /// Cost-scaling alpha for the CSA engines.
    pub alpha: i64,
    /// Threads of the lock-free CSA refine.
    pub csa_threads: usize,
    /// Waves per host round of the hybrid grid solver.
    pub cycle_waves: usize,
    /// Wave-pool width used by the `native-par` grid backend.
    pub par_threads: usize,
    pub tile_rows: usize,
    /// Host-round policy of the hybrid grid solver behind `native-par`:
    /// `Striped` runs the between-wave cancel/relabel on the worker's
    /// wave pool (bit-exact with `Seq`; `[gridflow] host_rounds`).
    pub host_rounds: HostRounds,
    /// Static (PR 3 tables) or adaptive (measurement-driven) routing.
    pub routing: RoutingMode,
    /// Adaptive mode: probe one decision in `probe_every` (0 disables
    /// probing after cold start).
    pub probe_every: usize,
    /// Adaptive mode: spill Large grid solves to `fifo-lockfree` when
    /// the shared wave pool has at least this many queued jobs (0 =
    /// spill whenever the check runs, useful in tests; has no effect in
    /// static mode).
    pub spill_depth: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            assign: [
                AssignBackend::Hungarian,
                AssignBackend::CsaLockfree,
                AssignBackend::CsaLockfree,
            ],
            grid: [GridBackend::Native, GridBackend::NativePar, GridBackend::NativePar],
            use_pjrt: false,
            pjrt_max_n: 64,
            alpha: 10,
            csa_threads: 2,
            cycle_waves: 512,
            par_threads: 4,
            tile_rows: 16,
            host_rounds: HostRounds::Seq,
            routing: RoutingMode::Static,
            probe_every: 8,
            spill_depth: 8,
        }
    }
}

// ---------------------------------------------------------------------------
// Per-worker routing state
// ---------------------------------------------------------------------------

/// EWMA penalty multiplier applied to a solve that returned an error:
/// the failed attempt's elapsed time (floored at [`MIN_FAILURE_SECS`],
/// so a fast-failing backend cannot look cheap) scaled so the backend
/// loses the winner contest until probes see it succeed again.
const FAILURE_PENALTY: f64 = 8.0;
const MIN_FAILURE_SECS: f64 = 0.050;

/// Per-worker backend state: every available engine instantiated from
/// the registry (scratch survives across requests), the routing config,
/// and the shared telemetry sink.
pub(crate) struct WorkerBackends {
    cfg: RouterConfig,
    backends: Vec<Box<dyn Backend>>,
    telemetry: Arc<TelemetrySink>,
    /// Clone of the shared wave pool, kept for the saturation probe
    /// (the `native-par` executor holds its own clone for tile work).
    wave_pool: Option<Arc<WorkerPool>>,
}

impl WorkerBackends {
    /// Build the worker's caches with a private telemetry sink (tests,
    /// spawn-baseline loadgen).  `wave_pool` is the shared persistent
    /// pool the `native-par` backend borrows (None: fall back to
    /// per-wave scoped threads).
    pub fn new(cfg: RouterConfig, wave_pool: Option<&Arc<WorkerPool>>) -> Self {
        let sink = Arc::new(TelemetrySink::new(cfg.probe_every));
        Self::with_telemetry(cfg, wave_pool, sink)
    }

    /// Build the worker's caches against a sink shared with the other
    /// workers — the production shape: all workers feed (and read) one
    /// set of EWMAs.
    pub fn with_telemetry(
        cfg: RouterConfig,
        wave_pool: Option<&Arc<WorkerPool>>,
        telemetry: Arc<TelemetrySink>,
    ) -> Self {
        let backends = BackendRegistry::standard().instantiate(&cfg, wave_pool);
        Self {
            cfg,
            backends,
            telemetry,
            wave_pool: wave_pool.map(Arc::clone),
        }
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.backends.iter().position(|b| b.name() == name)
    }

    /// Static routing: PJRT first for assignment instances that fit,
    /// then the per-class table — exactly the PR 3 dispatch.
    fn route_static(&self, class: SizeClass, instance: &ProblemInstance) -> &'static str {
        match Family::of(instance) {
            Family::Assignment => {
                if let Some(i) = self.index_of("pjrt") {
                    if self.backends[i].accepts(instance) {
                        return "pjrt";
                    }
                }
                self.cfg.assign[class.index()].name()
            }
            Family::Grid => self.cfg.grid[class.index()].name(),
        }
    }

    /// Adaptive routing: saturation spill first, then the telemetry
    /// sink's cold-start / probe / winner decision.
    fn route_adaptive(&self, class: SizeClass, instance: &ProblemInstance) -> &'static str {
        let family = Family::of(instance);
        if family == Family::Grid && class == SizeClass::Large {
            if let Some(pool) = &self.wave_pool {
                if pool.pending() >= self.cfg.spill_depth {
                    self.telemetry.record_spill();
                    return "fifo-lockfree";
                }
            }
        }
        let candidates: Vec<&'static str> = self
            .backends
            .iter()
            .filter(|b| b.family() == family && b.accepts(instance))
            .map(|b| b.name())
            .collect();
        self.telemetry.choose(family, class, &candidates)
    }

    /// Solve one request; returns the outcome plus the backend name
    /// that actually served it.  Every solve's latency (excluding queue
    /// delay) feeds the telemetry sink in both routing modes — that is
    /// what populates the per-backend route counts and EWMAs surfaced
    /// in `PoolReport::routes` and the CLI route table.
    pub fn solve(
        &mut self,
        class: SizeClass,
        instance: &ProblemInstance,
    ) -> Result<(SolveOutcome, &'static str)> {
        let name = match self.cfg.routing {
            RoutingMode::Static => self.route_static(class, instance),
            RoutingMode::Adaptive => self.route_adaptive(class, instance),
        };
        let idx = self
            .index_of(name)
            .ok_or_else(|| anyhow::anyhow!("backend {name:?} not available on this worker"))?;
        let t = Instant::now();
        let outcome = self.backends[idx].solve(instance);
        let elapsed = t.elapsed().as_secs_f64();
        match outcome {
            Ok(out) => {
                self.telemetry.record(Family::of(instance), class, name, elapsed);
                Ok((out, name))
            }
            Err(e) => {
                // A failing backend must still be measured: with no
                // sample its count stays 0 and adaptive cold start
                // would re-select it forever.  The penalty is finite
                // (not ∞) so later successful probes can rehabilitate
                // a backend that recovers.
                self.telemetry.record(
                    Family::of(instance),
                    class,
                    name,
                    elapsed.max(MIN_FAILURE_SECS) * FAILURE_PENALTY,
                );
                Err(e)
            }
        }
    }

    /// Test hook: build against an arbitrary registry (fault injection).
    #[cfg(test)]
    fn with_registry_for_tests(cfg: RouterConfig, registry: &BackendRegistry) -> Self {
        let telemetry = Arc::new(TelemetrySink::new(cfg.probe_every));
        let backends = registry.instantiate(&cfg, None);
        Self {
            cfg,
            backends,
            telemetry,
            wave_pool: None,
        }
    }

    #[cfg(test)]
    fn solve_named(&mut self, name: &str, instance: &ProblemInstance) -> Result<SolveOutcome> {
        let idx = self
            .index_of(name)
            .ok_or_else(|| anyhow::anyhow!("backend {name:?} not available"))?;
        self.backends[idx].solve(instance)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;
    use crate::maxflow::dinic::Dinic;
    use crate::util::Rng;
    use crate::workloads::{random_grid, uniform_costs};

    #[test]
    fn backend_names_roundtrip() {
        for b in [
            AssignBackend::Hungarian,
            AssignBackend::CsaSeq,
            AssignBackend::CsaLockfree,
            AssignBackend::WaveCsa,
        ] {
            assert_eq!(AssignBackend::parse(b.name()).unwrap(), b);
        }
        for b in [
            GridBackend::Native,
            GridBackend::NativePar,
            GridBackend::FifoLockfree,
        ] {
            assert_eq!(GridBackend::parse(b.name()).unwrap(), b);
        }
        assert!(AssignBackend::parse("nope").is_err());
        assert!(GridBackend::parse("nope").is_err());
    }

    #[test]
    fn registry_lists_every_engine_once() {
        let reg = BackendRegistry::standard();
        assert_eq!(
            reg.names(Family::Assignment),
            ["hungarian", "csa-seq", "csa-lockfree", "csa-wave", "pjrt"]
        );
        assert_eq!(
            reg.names(Family::Grid),
            ["native", "native-par", "fifo-lockfree"]
        );
        // Every static-table name resolves to a registered spec.
        for n in ["hungarian", "csa-seq", "csa-lockfree", "csa-wave"] {
            assert!(reg.names(Family::Assignment).contains(&n));
        }
        for n in ["native", "native-par", "fifo-lockfree"] {
            assert!(reg.names(Family::Grid).contains(&n));
        }
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn duplicate_registration_rejected() {
        let mut reg = BackendRegistry::standard();
        reg.register("hungarian", Family::Assignment, |_, _| None);
    }

    #[test]
    fn routes_by_class_and_solves_optimally() {
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        let mut rng = Rng::seeded(11);
        let inst = uniform_costs(&mut rng, 12, 50);
        let want = Hungarian.solve(&inst).unwrap().weight;
        for class in SizeClass::ALL {
            let (out, name) = backends
                .solve(class, &ProblemInstance::Assignment(inst.clone()))
                .unwrap();
            assert_eq!(out.weight(), Some(want), "class {}", class.name());
            let expected = RouterConfig::default().assign[class.index()].name();
            assert_eq!(name, expected);
        }
    }

    #[test]
    fn every_grid_backend_agrees_with_dinic() {
        let mut rng = Rng::seeded(12);
        let net = random_grid(&mut rng, 7, 7, 9, 0.3, 0.3);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap().value;
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        for b in [
            GridBackend::Native,
            GridBackend::NativePar,
            GridBackend::FifoLockfree,
        ] {
            let out = backends
                .solve_named(b.name(), &ProblemInstance::Grid(net.clone()))
                .unwrap();
            assert_eq!(out.flow(), Some(want), "backend {}", b.name());
        }
    }

    #[test]
    fn backend_rejects_wrong_family() {
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        let mut rng = Rng::seeded(13);
        let net = random_grid(&mut rng, 4, 4, 5, 0.3, 0.3);
        let err = backends
            .solve_named("hungarian", &ProblemInstance::Grid(net))
            .unwrap_err();
        assert!(err.to_string().contains("cannot serve"), "{err}");
    }

    #[test]
    fn adaptive_cold_start_covers_all_assignment_engines() {
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, None);
        let mut rng = Rng::seeded(14);
        let inst = uniform_costs(&mut rng, 10, 40);
        let want = Hungarian.solve(&inst).unwrap().weight;
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let (out, name) = backends
                .solve(SizeClass::Small, &ProblemInstance::Assignment(inst.clone()))
                .unwrap();
            assert_eq!(out.weight(), Some(want), "backend {name} suboptimal");
            seen.insert(name);
        }
        // use_pjrt = false → exactly the four native engines, each
        // probed once during cold start.
        assert_eq!(
            seen.into_iter().collect::<Vec<_>>(),
            ["csa-lockfree", "csa-seq", "csa-wave", "hungarian"]
        );
    }

    struct AlwaysFails;

    impl Backend for AlwaysFails {
        fn name(&self) -> &'static str {
            "always-fails"
        }

        fn family(&self) -> Family {
            Family::Assignment
        }

        fn solve(&mut self, _instance: &ProblemInstance) -> Result<SolveOutcome> {
            bail!("injected failure")
        }
    }

    /// A backend whose every solve errors must still get measured (with
    /// the failure penalty) — otherwise adaptive cold start, which
    /// prefers unmeasured candidates, would re-select it forever.
    #[test]
    fn failing_backend_is_demoted_not_repinned() {
        let mut reg = BackendRegistry::new();
        reg.register("always-fails", Family::Assignment, |_, _| {
            Some(Box::new(AlwaysFails))
        });
        reg.register("hungarian", Family::Assignment, |_, _| {
            Some(Box::new(HungarianBackend))
        });
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::with_registry_for_tests(cfg, &reg);
        let mut rng = Rng::seeded(16);
        let inst = ProblemInstance::Assignment(uniform_costs(&mut rng, 6, 20));
        // Cold start hits the broken engine first; the error propagates.
        let err = backends.solve(SizeClass::Small, &inst).unwrap_err();
        assert!(err.to_string().contains("injected failure"), "{err}");
        // But the failure was recorded (penalised), so the router cold
        // starts the healthy engine next and then keeps winning with it
        // instead of re-pinning the broken one.
        for _ in 0..3 {
            let (_, name) = backends.solve(SizeClass::Small, &inst).unwrap();
            assert_eq!(name, "hungarian");
        }
    }

    /// Saturation spill: with the shared wave pool's queue backed up
    /// past `spill_depth`, a Large grid solve is re-routed to the
    /// self-threaded `fifo-lockfree` engine — and the flow value is
    /// unchanged.
    #[test]
    fn large_grid_spills_to_lockfree_when_pool_saturated() {
        use std::sync::{Condvar, Mutex};

        let pool = Arc::new(WorkerPool::new(1));
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            spill_depth: 2,
            par_threads: 1,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, Some(&pool));

        let mut rng = Rng::seeded(15);
        let net = random_grid(&mut rng, 8, 8, 9, 0.3, 0.3);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap().value;

        // Saturate the 1-thread wave pool: the worker blocks on the
        // gate, two more jobs sit queued → pending() == 2 == spill_depth.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let blocked = {
            let pool = Arc::clone(&pool);
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || {
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                    .map(|_| {
                        let gate = Arc::clone(&gate);
                        Box::new(move || {
                            let (lock, cv) = &*gate;
                            let mut open = lock.lock().unwrap();
                            while !*open {
                                open = cv.wait(open).unwrap();
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.scope_run(jobs);
            })
        };
        while pool.pending() < 2 {
            std::thread::yield_now();
        }

        let (out, name) = backends
            .solve(SizeClass::Large, &ProblemInstance::Grid(net.clone()))
            .unwrap();
        assert_eq!(name, "fifo-lockfree", "saturated pool must spill");
        assert_eq!(out.flow(), Some(want), "spilled solve changed the flow");

        // Open the gate; once the pool drains, Large grids route
        // normally again (cold start: first un-measured grid engine).
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        blocked.join().unwrap();
        assert_eq!(pool.pending(), 0);
        let (_, name) = backends
            .solve(SizeClass::Large, &ProblemInstance::Grid(net))
            .unwrap();
        assert_ne!(name, "fifo-lockfree", "drained pool must not spill");
    }
}
