//! Backend routing: which solver engine serves a request.
//!
//! Every engine in the tree is wrapped in one [`Backend`] trait object
//! and registered exactly once in [`BackendRegistry::standard`] — that
//! function is the single place a new engine is added.  A registry is
//! instantiated **per worker** ([`WorkerBackends`]): executor scratch
//! (active lists, BFS buffers) survives across requests, and the
//! compiled PJRT artifact handle, which is `!Send`, lives and dies on
//! the worker thread that built it.
//!
//! Two routing modes sit on top (see [`RoutingMode`]):
//!
//! * **static** — the per-size-class tables in [`RouterConfig`]
//!   (`assign` / `grid`), with PJRT preferred for assignment instances
//!   that fit its padded size.  Bit-exact with the PR 3 service.
//! * **adaptive** — measurement-driven: per-(family × class × backend)
//!   latency EWMAs in the shared [`TelemetrySink`], ε-greedy cold-start
//!   probing, route-to-winner steady state, and saturation spill of
//!   Large grid solves to `fifo-lockfree` whenever the shared wave
//!   pool's queue depth is at or above [`RouterConfig::spill_depth`]
//!   (a saturated pool means `native-par`'s tile phases would queue
//!   behind other solves, so Hong's self-threaded CSR engine wins).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::assignment::{self, AssignmentSolver};
use crate::coordinator::PjrtAssignmentDriver;
use crate::graph::{GridCsrIndex, GridNetwork};
use crate::gridflow::warm::WarmState;
use crate::gridflow::{
    padded_class, BatchGridSolver, CapacityDelta, GridSolveReport, HostRounds, HybridGridSolver,
    NativeGridExecutor, NativeParGridExecutor,
};
use crate::maxflow::fifo::FifoPushRelabel;
use crate::maxflow::global_relabel::STRIPED_RELABEL_MIN_NODES;
use crate::maxflow::warm::{CsrDelta, CsrWarmState};
use crate::maxflow::{self, MaxFlowSolver};
use crate::parallel::ParTuning;
use crate::runtime::{ArtifactRegistry, BatchedGridDriver};
use crate::util::{CancelToken, Cancelled};
use crate::workloads::ProblemInstance;

use super::adaptive::{RoutingMode, TelemetrySink};
use super::fault::{backoff_delay, FaultPlan, FaultyBackend};
use super::pool::WorkerPool;
use super::shard::SizeClass;
use super::SolveOutcome;

/// The two problem families the service routes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Family {
    Assignment,
    Grid,
}

impl Family {
    pub const ALL: [Family; 2] = [Family::Assignment, Family::Grid];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            Family::Assignment => 0,
            Family::Grid => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Family::Assignment => "assignment",
            Family::Grid => "grid",
        }
    }

    pub fn of(instance: &ProblemInstance) -> Family {
        match instance {
            ProblemInstance::Assignment(_) => Family::Assignment,
            ProblemInstance::Grid(_) => Family::Grid,
        }
    }
}

/// One solver engine behind the service.  Implementations own whatever
/// state they want cached between requests on a worker (executor
/// scratch, device handles); they are built per worker thread and never
/// cross threads, so `!Send` members are fine.
pub trait Backend {
    /// Stable engine name — the routing tables, telemetry, and reports
    /// all key on it.
    fn name(&self) -> &'static str;

    fn family(&self) -> Family;

    /// Whether this backend can serve `instance` (e.g. PJRT only takes
    /// assignment instances that fit its padded size).  Backends are
    /// only offered instances of their own family.
    fn accepts(&self, instance: &ProblemInstance) -> bool {
        let _ = instance;
        true
    }

    /// Solve, polling `cancel` at whatever pause points the engine has
    /// (host-round boundaries for the iterative grid/CSR engines; fast
    /// direct solvers just check on entry).  A cancelled solve returns
    /// the typed [`Cancelled`] error — the router treats it as a
    /// deadline miss, not a backend fault (no penalty, no breaker, no
    /// retry).
    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome>;

    /// Solve a micro-batch of same-class instances in one dispatch,
    /// each slot under its **own** cancel token (per-job deadlines — a
    /// batch never inherits its slackest member's budget).  `None`, the
    /// default, means this backend has no batched path and the pool
    /// must dispatch per instance; `Some` carries one result per slot,
    /// in order, with a fired token surfacing as the typed
    /// [`Cancelled`] error in that slot only.
    fn solve_batch(
        &mut self,
        instances: &[&ProblemInstance],
        cancels: &[CancelToken],
    ) -> Option<Vec<Result<SolveOutcome>>> {
        let _ = (instances, cancels);
        None
    }
}

fn wrong_family(backend: &'static str, instance: &ProblemInstance) -> anyhow::Error {
    anyhow::anyhow!(
        "backend {backend} cannot serve a {} instance",
        Family::of(instance).name()
    )
}

// ---------------------------------------------------------------------------
// Assignment backends
// ---------------------------------------------------------------------------

struct HungarianBackend;

impl Backend for HungarianBackend {
    fn name(&self) -> &'static str {
        "hungarian"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        cancel.check()?;
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::hungarian::Hungarian.solve_traced(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct CsaSeqBackend {
    alpha: i64,
}

impl Backend for CsaSeqBackend {
    fn name(&self) -> &'static str {
        "csa-seq"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        cancel.check()?;
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::csa::SequentialCsa::with_alpha(self.alpha).solve_traced(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct CsaLockfreeBackend {
    alpha: i64,
    threads: usize,
}

impl Backend for CsaLockfreeBackend {
    fn name(&self) -> &'static str {
        "csa-lockfree"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        cancel.check()?;
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::csa_lockfree::LockFreeCsa {
                    alpha: self.alpha,
                    threads: self.threads,
                }
                .solve_traced(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct WaveCsaBackend {
    alpha: i64,
}

impl Backend for WaveCsaBackend {
    fn name(&self) -> &'static str {
        "csa-wave"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        cancel.check()?;
        match instance {
            ProblemInstance::Assignment(inst) => Ok(SolveOutcome::Assignment(
                assignment::wave::WaveCsa {
                    alpha: Some(self.alpha),
                }
                .solve_traced(inst)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

/// The PJRT device driver.  The artifact handle is `!Send` (like a CUDA
/// context); it is discovered and compiled once per worker, here.
struct PjrtBackend {
    driver: PjrtAssignmentDriver,
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn family(&self) -> Family {
        Family::Assignment
    }

    fn accepts(&self, instance: &ProblemInstance) -> bool {
        match instance {
            ProblemInstance::Assignment(inst) => inst.n <= self.driver.padded_n(),
            ProblemInstance::Grid(_) => false,
        }
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        cancel.check()?;
        match instance {
            ProblemInstance::Assignment(inst) => {
                let (result, _tel) = self.driver.solve(inst)?;
                crate::obs::record_assignment_stats("pjrt", &result.stats);
                Ok(SolveOutcome::Assignment(result))
            }
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

// ---------------------------------------------------------------------------
// Grid backends
// ---------------------------------------------------------------------------

struct NativeGridBackend {
    exec: NativeGridExecutor,
    cycle_waves: usize,
}

impl Backend for NativeGridBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn family(&self) -> Family {
        Family::Grid
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Grid(net) => Ok(SolveOutcome::Grid(
                HybridGridSolver::with_cycle(self.cycle_waves)
                    .with_cancel(cancel.clone())
                    .solve(net, &mut self.exec)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

struct NativeParGridBackend {
    exec: NativeParGridExecutor,
    cycle_waves: usize,
    /// `Striped` wires the worker's wave pool into the host rounds too
    /// (via `GridExecutor::host_pool`), so Large solves stop
    /// serialising on the between-wave BFS.  Bit-exact with `Seq`.
    host_rounds: HostRounds,
    /// Stripe balancing + commit parity discipline for the striped
    /// substrate (`[gridflow] stripe_balance` / `[gridflow] commit`).
    tuning: ParTuning,
}

impl Backend for NativeParGridBackend {
    fn name(&self) -> &'static str {
        "native-par"
    }

    fn family(&self) -> Family {
        Family::Grid
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Grid(net) => Ok(SolveOutcome::Grid(
                HybridGridSolver::with_cycle(self.cycle_waves)
                    .with_host_rounds(self.host_rounds)
                    .with_tuning(self.tuning)
                    .with_cancel(cancel.clone())
                    .solve(net, &mut self.exec)?,
            )),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

/// Hong's lock-free engine over the CSR conversion.  It spawns its own
/// scoped threads, so it stays fast when the shared wave pool is
/// saturated — which is exactly why the adaptive router spills to it.
struct FifoLockfreeBackend {
    threads: usize,
}

impl FifoLockfreeBackend {
    fn solve_grid(&self, net: &GridNetwork, cancel: &CancelToken) -> Result<GridSolveReport> {
        let mut g = net.to_flow_network();
        let stats = maxflow::lockfree::LockFree {
            threads: self.threads.max(1),
            cancel: Some(cancel.clone()),
            ..Default::default()
        }
        .solve_traced(&mut g)?;
        Ok(GridSolveReport {
            flow: stats.value,
            excess_total: net.excess_total(),
            host_rounds: stats.rounds,
            pushes: stats.pushes as i64,
            relabels: stats.relabels as i64,
            ..Default::default()
        })
    }
}

impl Backend for FifoLockfreeBackend {
    fn name(&self) -> &'static str {
        "fifo-lockfree"
    }

    fn family(&self) -> Family {
        Family::Grid
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Grid(net) => Ok(SolveOutcome::Grid(self.solve_grid(net, cancel)?)),
            other => Err(wrong_family(self.name(), other)),
        }
    }
}

/// The batched device backend: grid micro-batches run as joint padded
/// dispatches on a [`BatchedGridDriver`] (the deterministic
/// host-simulated device today; a PJRT artifact compiled for the padded
/// batch shape slots in behind the same driver).  Single solves run as
/// a batch of one, so the adaptive router's EWMA measures this engine
/// on exactly the path batches take.  Instantiated only when
/// `[service] batch_max > 1` — defaults leave routing untouched.
struct BatchedGridBackend {
    cycle_waves: usize,
    /// Drivers cached per padded class: staging literals stay warm and
    /// the dispatch stats accumulate across requests.
    drivers: std::collections::BTreeMap<(usize, usize), BatchedGridDriver>,
}

impl BatchedGridBackend {
    fn new(cycle_waves: usize) -> Self {
        Self {
            cycle_waves,
            drivers: std::collections::BTreeMap::new(),
        }
    }

    fn solve_grids(
        &mut self,
        nets: &[&GridNetwork],
        cancels: &[CancelToken],
    ) -> Result<Vec<Result<GridSolveReport>>> {
        let class = padded_class(nets);
        let driver = self
            .drivers
            .entry(class)
            .or_insert_with(|| BatchedGridDriver::for_class(class.0, class.1));
        let before = driver.stats();
        let tokens: Vec<Option<CancelToken>> = cancels.iter().cloned().map(Some).collect();
        let out =
            BatchGridSolver::with_cycle(self.cycle_waves).solve_batch(nets, &tokens, driver)?;
        crate::obs::record_batch_dispatches(&before, &driver.stats());
        Ok(out)
    }
}

impl Backend for BatchedGridBackend {
    fn name(&self) -> &'static str {
        "grid-batch"
    }

    fn family(&self) -> Family {
        Family::Grid
    }

    fn solve(&mut self, instance: &ProblemInstance, cancel: &CancelToken) -> Result<SolveOutcome> {
        match instance {
            ProblemInstance::Grid(net) => {
                let results = self.solve_grids(&[net], std::slice::from_ref(cancel))?;
                let report = results.into_iter().next().expect("batch of one")?;
                Ok(SolveOutcome::Grid(report))
            }
            other => Err(wrong_family(self.name(), other)),
        }
    }

    fn solve_batch(
        &mut self,
        instances: &[&ProblemInstance],
        cancels: &[CancelToken],
    ) -> Option<Vec<Result<SolveOutcome>>> {
        let mut nets = Vec::with_capacity(instances.len());
        for inst in instances {
            match inst {
                ProblemInstance::Grid(net) => nets.push(*net),
                // A mixed batch is a pool bug; refuse the batched path
                // and let per-instance dispatch sort it out.
                _ => return None,
            }
        }
        match self.solve_grids(&nets, cancels) {
            Ok(results) => Some(
                results
                    .into_iter()
                    .map(|r| r.map(SolveOutcome::Grid))
                    .collect(),
            ),
            // Whole-dispatch failure (shape refused, driver died):
            // decline — the pool re-solves every slot per instance.
            Err(_) => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

type BuildFn = fn(&RouterConfig, Option<&Arc<WorkerPool>>) -> Option<Box<dyn Backend>>;

/// One registered engine: its stable name, family, and per-worker
/// constructor.  The constructor may return `None` for backends that
/// are unavailable in this process (PJRT without artifacts).
pub struct BackendSpec {
    pub name: &'static str,
    pub family: Family,
    build: BuildFn,
}

/// The engine catalogue.  [`BackendRegistry::standard`] is the single
/// registration point: adding an engine there makes it routable,
/// measurable, and reportable everywhere at once.
pub struct BackendRegistry {
    specs: Vec<BackendSpec>,
}

impl BackendRegistry {
    pub fn new() -> Self {
        Self { specs: Vec::new() }
    }

    pub fn register(&mut self, name: &'static str, family: Family, build: BuildFn) {
        assert!(
            self.specs.iter().all(|s| s.name != name),
            "backend {name:?} registered twice"
        );
        self.specs.push(BackendSpec {
            name,
            family,
            build,
        });
    }

    /// Every in-tree engine, registered once.
    pub fn standard() -> Self {
        let mut r = Self::new();
        r.register("hungarian", Family::Assignment, |_, _| {
            Some(Box::new(HungarianBackend))
        });
        r.register("csa-seq", Family::Assignment, |cfg, _| {
            Some(Box::new(CsaSeqBackend { alpha: cfg.alpha }))
        });
        r.register("csa-lockfree", Family::Assignment, |cfg, _| {
            Some(Box::new(CsaLockfreeBackend {
                alpha: cfg.alpha,
                threads: cfg.csa_threads,
            }))
        });
        r.register("csa-wave", Family::Assignment, |cfg, _| {
            Some(Box::new(WaveCsaBackend { alpha: cfg.alpha }))
        });
        // PJRT discovery happens once, here — not per request; absent
        // artifacts simply leave the backend unregistered on the worker.
        r.register("pjrt", Family::Assignment, |cfg, _| {
            if !cfg.use_pjrt {
                return None;
            }
            ArtifactRegistry::discover()
                .ok()
                .and_then(|reg| PjrtAssignmentDriver::for_size(&reg, cfg.pjrt_max_n).ok())
                .map(|mut d| {
                    d.alpha = cfg.alpha;
                    Box::new(PjrtBackend { driver: d }) as Box<dyn Backend>
                })
        });
        r.register("native", Family::Grid, |cfg, _| {
            Some(Box::new(NativeGridBackend {
                exec: NativeGridExecutor::default(),
                cycle_waves: cfg.cycle_waves,
            }))
        });
        r.register("native-par", Family::Grid, |cfg, pool| {
            let mut exec = NativeParGridExecutor::new(cfg.par_threads, cfg.tile_rows)
                .with_tuning(cfg.tuning);
            if let Some(pool) = pool {
                exec = exec.with_pool(Arc::clone(pool));
            }
            Some(Box::new(NativeParGridBackend {
                exec,
                cycle_waves: cfg.cycle_waves,
                host_rounds: cfg.host_rounds,
                tuning: cfg.tuning,
            }))
        });
        r.register("fifo-lockfree", Family::Grid, |cfg, _| {
            Some(Box::new(FifoLockfreeBackend {
                threads: cfg.par_threads.max(1),
            }))
        });
        // Config-gated like PJRT: with batching off (`batch_max <= 1`,
        // the default) the backend does not instantiate, so routing —
        // static tables, adaptive candidates, fallback chains — is
        // bit-identical to the pre-batching service.
        r.register("grid-batch", Family::Grid, |cfg, _| {
            if cfg.batch_max <= 1 {
                return None;
            }
            Some(Box::new(BatchedGridBackend::new(cfg.cycle_waves)))
        });
        r
    }

    /// Registered names for a family (whether or not they build on a
    /// given worker).
    pub fn names(&self, family: Family) -> Vec<&'static str> {
        self.specs
            .iter()
            .filter(|s| s.family == family)
            .map(|s| s.name)
            .collect()
    }

    /// Build every available backend for one worker, in registration
    /// order.  When a [`FaultPlan`] targets one of them, the built
    /// backend is wrapped in a [`FaultyBackend`] — the injection point
    /// of the chaos harness, inside the registry so faults flow through
    /// the production routing/retry/breaker machinery.
    fn instantiate(
        &self,
        cfg: &RouterConfig,
        pool: Option<&Arc<WorkerPool>>,
    ) -> Vec<Box<dyn Backend>> {
        self.specs
            .iter()
            .filter_map(|s| {
                let built = (s.build)(cfg, pool)?;
                Some(match &cfg.fault {
                    Some(plan) if plan.target == s.name => {
                        Box::new(FaultyBackend::wrap(built, plan.clone())) as Box<dyn Backend>
                    }
                    _ => built,
                })
            })
            .collect()
    }
}

impl Default for BackendRegistry {
    fn default() -> Self {
        Self::standard()
    }
}

// ---------------------------------------------------------------------------
// Static routing tables (config surface, unchanged from PR 3)
// ---------------------------------------------------------------------------

/// Native assignment backends for the static table (the PJRT driver is
/// layered on top via [`RouterConfig::use_pjrt`], mirroring the hybrid
/// drivers' Auto mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignBackend {
    Hungarian,
    CsaSeq,
    CsaLockfree,
    WaveCsa,
}

impl AssignBackend {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "hungarian" => AssignBackend::Hungarian,
            "csa-seq" => AssignBackend::CsaSeq,
            "csa-lockfree" => AssignBackend::CsaLockfree,
            "csa-wave" => AssignBackend::WaveCsa,
            other => bail!(
                "unknown assignment backend {other:?} \
                 (expected hungarian, csa-seq, csa-lockfree, csa-wave)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AssignBackend::Hungarian => "hungarian",
            AssignBackend::CsaSeq => "csa-seq",
            AssignBackend::CsaLockfree => "csa-lockfree",
            AssignBackend::WaveCsa => "csa-wave",
        }
    }
}

/// Grid max-flow backends for the static table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridBackend {
    /// Sequential native wave engine.
    Native,
    /// Tiled multi-threaded wave engine on the shared worker pool
    /// (bit-exact with `Native`).
    NativePar,
    /// Hong's lock-free engine over the CSR conversion.
    FifoLockfree,
    /// Batched device dispatches (bit-exact with `Native`); requires
    /// `batch_max > 1` or the backend does not instantiate and the
    /// fallback chain serves the request.
    Batch,
}

impl GridBackend {
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "native" => GridBackend::Native,
            "native-par" => GridBackend::NativePar,
            "fifo-lockfree" => GridBackend::FifoLockfree,
            "grid-batch" => GridBackend::Batch,
            other => bail!(
                "unknown grid backend {other:?} \
                 (expected native, native-par, fifo-lockfree, grid-batch)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            GridBackend::Native => "native",
            GridBackend::NativePar => "native-par",
            GridBackend::FifoLockfree => "fifo-lockfree",
            GridBackend::Batch => "grid-batch",
        }
    }
}

/// Routing tables + engine tunables, one copy per worker.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Static assignment backend per size class, indexed by
    /// [`SizeClass::index`].  Ignored in adaptive mode.
    pub assign: [AssignBackend; 3],
    /// Static grid backend per size class.  Ignored in adaptive mode.
    pub grid: [GridBackend; 3],
    /// Prefer the PJRT driver for assignment instances that fit its
    /// padded size, falling back to the native table on any miss.
    pub use_pjrt: bool,
    /// Size the per-worker PJRT driver is built for.
    pub pjrt_max_n: usize,
    /// Cost-scaling alpha for the CSA engines.
    pub alpha: i64,
    /// Threads of the lock-free CSA refine.
    pub csa_threads: usize,
    /// Waves per host round of the hybrid grid solver.
    pub cycle_waves: usize,
    /// Wave-pool width used by the `native-par` grid backend.
    pub par_threads: usize,
    pub tile_rows: usize,
    /// Host-round policy of the hybrid grid solver behind `native-par`:
    /// `Striped` runs the between-wave cancel/relabel on the worker's
    /// wave pool (bit-exact with `Seq`; `[gridflow] host_rounds`).
    pub host_rounds: HostRounds,
    /// Striped-substrate tuning for the grid engines behind
    /// `native-par`: stripe balancing (`[gridflow] stripe_balance`,
    /// fixed|weighted) and owner-commit parity (`[gridflow] commit`,
    /// two_pass|merged).  The default reproduces the pre-tuning
    /// behaviour bit for bit.
    pub tuning: ParTuning,
    /// Node-count gate below which the CSR engines' periodic global
    /// relabel stays on the sequential BFS even when a pool is attached
    /// (`[maxflow] striped_relabel_min_nodes`).
    pub striped_relabel_min_nodes: usize,
    /// Static (PR 3 tables) or adaptive (measurement-driven) routing.
    pub routing: RoutingMode,
    /// Adaptive mode: probe one decision in `probe_every` (0 disables
    /// probing after cold start).
    pub probe_every: usize,
    /// Adaptive mode: spill Large grid solves to `fifo-lockfree` when
    /// the shared wave pool has at least this many queued jobs (0 =
    /// spill whenever the check runs, useful in tests; has no effect in
    /// static mode).
    pub spill_depth: usize,
    /// Retries after a failed/panicked solve, each routed to the
    /// next-best *different* backend (0 = fail fast).
    pub max_retries: u32,
    /// Base of the deterministic exponential backoff between retries,
    /// in milliseconds (0 = retry immediately).
    pub retry_backoff_ms: u64,
    /// Consecutive failures that trip a per-(family × class × backend)
    /// circuit breaker (0 disables breakers).
    pub breaker_threshold: usize,
    /// Completed requests an open breaker waits before admitting a
    /// half-open probe (request-counted, not wall clock).
    pub breaker_cooldown: usize,
    /// Chaos harness: wrap the targeted backend in a [`FaultyBackend`]
    /// driven by this plan (`loadgen --chaos <seed>`).
    pub fault: Option<FaultPlan>,
    /// Most grid solves one device dispatch may carry (`[service]
    /// batch_max`, `loadgen --batch-max`).  At the default 1 the
    /// `grid-batch` backend does not instantiate and the shard queues
    /// never cut batches — the service is bit-identical to the
    /// pre-batching build.
    pub batch_max: usize,
    /// Longest a cut batch may linger waiting for compatible jobs, in
    /// microseconds (`[service] batch_linger_us`).  The reserved
    /// real-time lane (worker 0 when `workers >= 2`) never lingers.
    pub batch_linger_us: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            assign: [
                AssignBackend::Hungarian,
                AssignBackend::CsaLockfree,
                AssignBackend::CsaLockfree,
            ],
            grid: [GridBackend::Native, GridBackend::NativePar, GridBackend::NativePar],
            use_pjrt: false,
            pjrt_max_n: 64,
            alpha: 10,
            csa_threads: 2,
            cycle_waves: 512,
            par_threads: 4,
            tile_rows: 16,
            host_rounds: HostRounds::Seq,
            tuning: ParTuning::default(),
            striped_relabel_min_nodes: STRIPED_RELABEL_MIN_NODES,
            routing: RoutingMode::Static,
            probe_every: 8,
            spill_depth: 8,
            max_retries: 2,
            retry_backoff_ms: 2,
            breaker_threshold: 3,
            breaker_cooldown: 8,
            fault: None,
            batch_max: 1,
            batch_linger_us: 200,
        }
    }
}

// ---------------------------------------------------------------------------
// Per-worker routing state
// ---------------------------------------------------------------------------

/// EWMA penalty multiplier applied to a solve that returned an error:
/// the failed attempt's elapsed time (floored at [`MIN_FAILURE_SECS`],
/// so a fast-failing backend cannot look cheap) scaled so the backend
/// loses the winner contest until probes see it succeed again.
const FAILURE_PENALTY: f64 = 8.0;
const MIN_FAILURE_SECS: f64 = 0.050;

/// A served request: the outcome plus how hard the service had to work
/// for it (retries taken, open breakers routed around).
#[derive(Debug)]
pub(crate) struct SolveAttempts {
    pub outcome: SolveOutcome,
    /// Backend that finally served the request.
    pub backend: &'static str,
    pub retries: u32,
    pub breaker_skips: u32,
}

/// A request that exhausted its attempts (or was cancelled).
#[derive(Debug)]
pub(crate) struct SolveFailure {
    /// Human-readable description of the *last* attempt's failure.
    pub error: String,
    pub retries: u32,
    /// The solve was cancelled (deadline), not a backend fault.
    pub cancelled: bool,
}

impl std::fmt::Display for SolveFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.error)
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Per-worker backend state: every available engine instantiated from
/// the registry (scratch survives across requests), the routing config,
/// and the shared telemetry sink.
pub(crate) struct WorkerBackends {
    cfg: RouterConfig,
    backends: Vec<Box<dyn Backend>>,
    telemetry: Arc<TelemetrySink>,
    /// Clone of the shared wave pool, kept for the saturation probe
    /// (the `native-par` executor holds its own clone for tile work).
    wave_pool: Option<Arc<WorkerPool>>,
}

impl WorkerBackends {
    /// Build the worker's caches with a private telemetry sink (tests,
    /// spawn-baseline loadgen).  `wave_pool` is the shared persistent
    /// pool the `native-par` backend borrows (None: fall back to
    /// per-wave scoped threads).
    pub fn new(cfg: RouterConfig, wave_pool: Option<&Arc<WorkerPool>>) -> Self {
        let sink = Arc::new(TelemetrySink::with_breaker(
            cfg.probe_every,
            cfg.breaker_threshold,
            cfg.breaker_cooldown,
        ));
        Self::with_telemetry(cfg, wave_pool, sink)
    }

    /// Build the worker's caches against a sink shared with the other
    /// workers — the production shape: all workers feed (and read) one
    /// set of EWMAs.
    pub fn with_telemetry(
        cfg: RouterConfig,
        wave_pool: Option<&Arc<WorkerPool>>,
        telemetry: Arc<TelemetrySink>,
    ) -> Self {
        let backends = BackendRegistry::standard().instantiate(&cfg, wave_pool);
        Self {
            cfg,
            backends,
            telemetry,
            wave_pool: wave_pool.map(Arc::clone),
        }
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.backends.iter().position(|b| b.name() == name)
    }

    /// Static routing: PJRT first for assignment instances that fit,
    /// then the per-class table — exactly the PR 3 dispatch.
    fn route_static(&self, class: SizeClass, instance: &ProblemInstance) -> &'static str {
        match Family::of(instance) {
            Family::Assignment => {
                if let Some(i) = self.index_of("pjrt") {
                    if self.backends[i].accepts(instance) {
                        return "pjrt";
                    }
                }
                self.cfg.assign[class.index()].name()
            }
            Family::Grid => self.cfg.grid[class.index()].name(),
        }
    }

    /// Registered backends that can serve this instance, in
    /// registration order — the fallback chain.
    fn family_candidates(&self, family: Family, instance: &ProblemInstance) -> Vec<&'static str> {
        self.backends
            .iter()
            .filter(|b| b.family() == family && b.accepts(instance))
            .map(|b| b.name())
            .collect()
    }

    /// Adaptive routing: saturation spill first, then the telemetry
    /// sink's cold-start / probe / winner decision over the candidates
    /// whose breakers admit traffic (all of them, if every breaker for
    /// the pair is open — a guess beats an unconditional failure).
    fn route_adaptive(
        &self,
        class: SizeClass,
        instance: &ProblemInstance,
        skips: &mut u32,
    ) -> &'static str {
        let family = Family::of(instance);
        if family == Family::Grid && class == SizeClass::Large {
            if let Some(pool) = &self.wave_pool {
                if pool.pending() >= self.cfg.spill_depth {
                    self.telemetry.record_spill();
                    return "fifo-lockfree";
                }
            }
        }
        let candidates = self.family_candidates(family, instance);
        let allowed: Vec<&'static str> = candidates
            .iter()
            .copied()
            .filter(|&n| self.telemetry.breaker_allows(family, class, n))
            .collect();
        let pick_from = if allowed.is_empty() { &candidates } else { &allowed };
        *skips += (candidates.len() - pick_from.len()) as u32;
        self.telemetry.choose(family, class, pick_from)
    }

    /// First-attempt route: the mode's usual decision, with open
    /// breakers routed around in both modes.
    fn primary_route(
        &self,
        class: SizeClass,
        instance: &ProblemInstance,
        skips: &mut u32,
    ) -> &'static str {
        match self.cfg.routing {
            RoutingMode::Adaptive => self.route_adaptive(class, instance, skips),
            RoutingMode::Static => {
                let name = self.route_static(class, instance);
                let family = Family::of(instance);
                if self.telemetry.breaker_allows(family, class, name) {
                    return name;
                }
                // The table's pick has an open breaker: take the first
                // registered alternative whose breaker admits traffic
                // (or the original pick if every breaker is open).
                match self
                    .family_candidates(family, instance)
                    .into_iter()
                    .find(|&n| n != name && self.telemetry.breaker_allows(family, class, n))
                {
                    Some(alt) => {
                        *skips += 1;
                        alt
                    }
                    None => name,
                }
            }
        }
    }

    /// Next backend for a retry: the first candidate (registration
    /// order) not yet tried for this request, preferring ones whose
    /// breaker admits traffic.  `None` once every candidate was tried.
    fn next_fallback(
        &self,
        family: Family,
        class: SizeClass,
        instance: &ProblemInstance,
        tried: &[&'static str],
        skips: &mut u32,
    ) -> Option<&'static str> {
        let untried: Vec<&'static str> = self
            .family_candidates(family, instance)
            .into_iter()
            .filter(|n| !tried.contains(n))
            .collect();
        match untried
            .iter()
            .position(|&n| self.telemetry.breaker_allows(family, class, n))
        {
            Some(i) => {
                *skips += i as u32;
                Some(untried[i])
            }
            None => untried.first().copied(),
        }
    }

    /// Serve one request end to end: route (around open breakers),
    /// solve with per-attempt panic isolation, and on failure retry up
    /// to `max_retries` times with deterministic exponential backoff,
    /// each retry on the next untried backend of the fallback chain.
    ///
    /// Every attempt's latency (excluding queue delay) feeds the
    /// telemetry sink in both routing modes — that is what populates
    /// the per-backend route counts and EWMAs surfaced in
    /// `PoolReport::routes` and the CLI route table.  Failed attempts
    /// are measured with the failure penalty (a failing backend must
    /// not look cheap, nor stay unmeasured and cold-start forever) and
    /// advance that backend's breaker; a [`Cancelled`] solve is a
    /// deadline miss, not a backend fault — no penalty, no breaker
    /// strike, no retry.
    pub fn solve(
        &mut self,
        class: SizeClass,
        instance: &ProblemInstance,
        cancel: &CancelToken,
    ) -> Result<SolveAttempts, SolveFailure> {
        let family = Family::of(instance);
        let mut tried: Vec<&'static str> = Vec::new();
        let mut breaker_skips = 0u32;
        let mut retries = 0u32;
        let mut last_err = String::from("no backend available for this request");
        for attempt in 0..=self.cfg.max_retries {
            let name = if attempt == 0 {
                self.primary_route(class, instance, &mut breaker_skips)
            } else {
                match self.next_fallback(family, class, instance, &tried, &mut breaker_skips) {
                    Some(n) => n,
                    None => break, // fallback chain exhausted
                }
            };
            if attempt > 0 {
                if cancel.is_cancelled() {
                    self.telemetry.request_completed(family, class);
                    return Err(SolveFailure {
                        error: Cancelled.to_string(),
                        retries,
                        cancelled: true,
                    });
                }
                // Back off — but never past the request's deadline: the
                // sleep is clamped to the remaining budget, and a
                // request whose budget dies mid-backoff is reported as a
                // deadline miss without burning a retry on an attempt
                // the client has already given up on.
                let mut delay = backoff_delay(self.cfg.retry_backoff_ms, attempt);
                if let Some(dl) = cancel.deadline() {
                    delay = delay.min(dl.saturating_duration_since(Instant::now()));
                }
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                }
                if cancel.is_cancelled() {
                    self.telemetry.request_completed(family, class);
                    return Err(SolveFailure {
                        error: Cancelled.to_string(),
                        retries,
                        cancelled: true,
                    });
                }
                retries += 1;
            }
            let Some(idx) = self.index_of(name) else {
                tried.push(name);
                last_err = format!("backend {name:?} not available on this worker");
                continue;
            };
            tried.push(name);
            let t = Instant::now();
            // Panic isolation per attempt: a panicking backend becomes
            // a failed attempt (retried on the fallback), not a dead
            // solver worker.  The engine's scratch is rebuilt lazily by
            // its next solve, so unwind-safety is not a concern here.
            let backend = &mut self.backends[idx];
            let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                backend.solve(instance, cancel)
            }));
            let elapsed = t.elapsed().as_secs_f64();
            match solved {
                Ok(Ok(out)) => {
                    self.telemetry.record(family, class, name, elapsed);
                    self.telemetry.record_breaker_success(family, class, name);
                    self.telemetry.request_completed(family, class);
                    return Ok(SolveAttempts {
                        outcome: out,
                        backend: name,
                        retries,
                        breaker_skips,
                    });
                }
                Ok(Err(e)) if Cancelled::caused(&e) => {
                    self.telemetry.request_completed(family, class);
                    return Err(SolveFailure {
                        error: format!("{e:#}"),
                        retries,
                        cancelled: true,
                    });
                }
                Ok(Err(e)) => {
                    self.telemetry.record(
                        family,
                        class,
                        name,
                        elapsed.max(MIN_FAILURE_SECS) * FAILURE_PENALTY,
                    );
                    self.telemetry.record_breaker_failure(family, class, name);
                    last_err = format!("solver error: {e:#}");
                }
                Err(payload) => {
                    self.telemetry.record(
                        family,
                        class,
                        name,
                        elapsed.max(MIN_FAILURE_SECS) * FAILURE_PENALTY,
                    );
                    self.telemetry.record_breaker_failure(family, class, name);
                    last_err = format!("solver panicked: {}", panic_message(payload.as_ref()));
                }
            }
        }
        self.telemetry.request_completed(family, class);
        Err(SolveFailure {
            error: last_err,
            retries,
            cancelled: false,
        })
    }

    /// Serve a batch cut from the shard queues as one joint device
    /// dispatch on the `grid-batch` backend.  Returns `None` when the
    /// batch should be served per-instance instead: the backend is not
    /// instantiated (`batch_max <= 1`), the batch is a singleton, this
    /// class's breaker is open, or — in adaptive mode — the telemetry
    /// sink's EWMA arbitration would not route this class to the
    /// batched backend anyway.  Per-slot outcomes mirror [`Self::solve`]'s
    /// accounting with the joint dispatch cost attributed evenly across
    /// slots; a non-cancelled failed slot does *not* complete the
    /// request here because the caller re-solves it per instance on the
    /// ordinary fallback chain.
    pub(crate) fn solve_batch(
        &mut self,
        class: SizeClass,
        instances: &[ProblemInstance],
        cancels: &[CancelToken],
    ) -> Option<Vec<Result<SolveAttempts, SolveFailure>>> {
        if instances.len() < 2 {
            return None;
        }
        let family = Family::of(&instances[0]);
        let idx = self.index_of("grid-batch")?;
        if self.cfg.routing == RoutingMode::Adaptive {
            let mut skips = 0u32;
            if self.route_adaptive(class, &instances[0], &mut skips) != "grid-batch" {
                return None;
            }
        }
        if !self.telemetry.breaker_allows(family, class, "grid-batch") {
            return None;
        }
        let refs: Vec<&ProblemInstance> = instances.iter().collect();
        let t = Instant::now();
        let backend = &mut self.backends[idx];
        let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend.solve_batch(&refs, cancels)
        }));
        let per_slot = t.elapsed().as_secs_f64() / instances.len() as f64;
        let results = match solved {
            Ok(Some(results)) => results,
            // The backend declined the batch (mixed families): solve
            // per instance, no telemetry — nothing was attempted.
            Ok(None) => return None,
            Err(_) => {
                // A panicking dispatch is one failed attempt against
                // the backend; every slot re-solves on the fallback
                // chain via the caller.
                self.telemetry.record(
                    family,
                    class,
                    "grid-batch",
                    per_slot.max(MIN_FAILURE_SECS) * FAILURE_PENALTY,
                );
                self.telemetry.record_breaker_failure(family, class, "grid-batch");
                return None;
            }
        };
        Some(
            results
                .into_iter()
                .map(|slot| match slot {
                    Ok(out) => {
                        self.telemetry.record(family, class, "grid-batch", per_slot);
                        self.telemetry.record_breaker_success(family, class, "grid-batch");
                        self.telemetry.request_completed(family, class);
                        Ok(SolveAttempts {
                            outcome: out,
                            backend: "grid-batch",
                            retries: 0,
                            breaker_skips: 0,
                        })
                    }
                    Err(e) if Cancelled::caused(&e) => {
                        self.telemetry.request_completed(family, class);
                        Err(SolveFailure {
                            error: format!("{e:#}"),
                            retries: 0,
                            cancelled: true,
                        })
                    }
                    Err(e) => {
                        self.telemetry.record(
                            family,
                            class,
                            "grid-batch",
                            per_slot.max(MIN_FAILURE_SECS) * FAILURE_PENALTY,
                        );
                        self.telemetry.record_breaker_failure(family, class, "grid-batch");
                        Err(SolveFailure {
                            error: format!("solver error: {e:#}"),
                            retries: 0,
                            cancelled: false,
                        })
                    }
                })
                .collect(),
        )
    }

    /// Test hook: build against an arbitrary registry (fault injection).
    #[cfg(test)]
    fn with_registry_for_tests(cfg: RouterConfig, registry: &BackendRegistry) -> Self {
        let telemetry = Arc::new(TelemetrySink::with_breaker(
            cfg.probe_every,
            cfg.breaker_threshold,
            cfg.breaker_cooldown,
        ));
        let backends = registry.instantiate(&cfg, None);
        Self {
            cfg,
            backends,
            telemetry,
            wave_pool: None,
        }
    }

    #[cfg(test)]
    fn solve_named(&mut self, name: &str, instance: &ProblemInstance) -> Result<SolveOutcome> {
        let idx = self
            .index_of(name)
            .ok_or_else(|| anyhow::anyhow!("backend {name:?} not available"))?;
        self.backends[idx].solve(instance, &CancelToken::new())
    }

    #[cfg(test)]
    fn telemetry(&self) -> &TelemetrySink {
        &self.telemetry
    }

    /// Cold-solve a grid instance and open a warm-start session for it.
    ///
    /// Sessions bypass adaptive routing, retries, and telemetry on
    /// purpose: the residual cache is engine-shaped, so the engine must
    /// stay fixed for the session's life — the static grid table for
    /// this size class decides it, even in adaptive mode.  `native` and
    /// `native-par` keep a [`WarmState`] of the wire state;
    /// `fifo-lockfree` keeps a [`CsrWarmState`] served by the
    /// *sequential* FIFO engine (`fifo+global`) — the lock-free engine
    /// snapshots capacities into atomics and cannot resume a repaired
    /// preflow, and the max-flow value is unique, so the session's
    /// replies still match the cold backend exactly.
    pub fn solve_session_open(
        &mut self,
        class: SizeClass,
        net: &GridNetwork,
        cancel: &CancelToken,
    ) -> Result<(SolveOutcome, SessionState, &'static str)> {
        match self.cfg.grid[class.index()] {
            // The batched backend keeps no warm state (every dispatch
            // re-packs the wire literal), so sessions opened under it
            // run on its bit-exact native twin.
            GridBackend::Native | GridBackend::Batch => {
                let solver = HybridGridSolver::with_cycle(self.cfg.cycle_waves)
                    .with_cancel(cancel.clone());
                let mut exec = NativeGridExecutor::default();
                let (report, warm) = WarmState::solve_cold(net.clone(), &solver, &mut exec)?;
                Ok((
                    SolveOutcome::Grid(report),
                    SessionState::Grid(Box::new(warm)),
                    "native",
                ))
            }
            GridBackend::NativePar => {
                let solver = HybridGridSolver::with_cycle(self.cfg.cycle_waves)
                    .with_host_rounds(self.cfg.host_rounds)
                    .with_tuning(self.cfg.tuning)
                    .with_cancel(cancel.clone());
                let mut exec = self.session_par_exec();
                let (report, warm) = WarmState::solve_cold(net.clone(), &solver, &mut exec)?;
                Ok((
                    SolveOutcome::Grid(report),
                    SessionState::Grid(Box::new(warm)),
                    "native-par",
                ))
            }
            GridBackend::FifoLockfree => {
                let (g, index) = net.to_flow_network_indexed();
                let engine = self.session_fifo(cancel);
                let (stats, warm) = CsrWarmState::solve_cold(g, &engine)?;
                let report = GridSolveReport {
                    flow: stats.value,
                    excess_total: net.excess_total(),
                    host_rounds: stats.rounds,
                    pushes: stats.pushes as i64,
                    relabels: stats.relabels as i64,
                    ..Default::default()
                };
                Ok((
                    SolveOutcome::Grid(report),
                    SessionState::Csr {
                        warm: Box::new(warm),
                        index,
                    },
                    "fifo+global",
                ))
            }
        }
    }

    /// Apply a delta update to an open session: repair the cached
    /// residual state locally and resume the engine from the affected
    /// frontier.  The caller owns error handling; on any `Err` the
    /// session state may be partially repaired and must be dropped.
    pub fn solve_session_update(
        &mut self,
        class: SizeClass,
        state: &mut SessionState,
        deltas: &[CapacityDelta],
        cancel: &CancelToken,
    ) -> Result<(SolveOutcome, &'static str)> {
        match state {
            SessionState::Grid(warm) => {
                let (solver, name) = match self.cfg.grid[class.index()] {
                    GridBackend::NativePar => (
                        HybridGridSolver::with_cycle(self.cfg.cycle_waves)
                            .with_host_rounds(self.cfg.host_rounds)
                            .with_tuning(self.cfg.tuning)
                            .with_cancel(cancel.clone()),
                        "native-par",
                    ),
                    _ => (
                        HybridGridSolver::with_cycle(self.cfg.cycle_waves)
                            .with_cancel(cancel.clone()),
                        "native",
                    ),
                };
                let t = crate::util::Timer::start();
                let mut report = if name == "native-par" {
                    let mut exec = self.session_par_exec();
                    warm.update(deltas, &solver, &mut exec)?
                } else {
                    let mut exec = NativeGridExecutor::default();
                    warm.update(deltas, &solver, &mut exec)?
                };
                // Whatever `update` spent outside the traced engine
                // phases is the delta apply + residual repair work.
                let repair = (t.elapsed() - report.phases.total_seconds()).max(0.0);
                report.phases.add(crate::obs::Phase::SessionRepair, repair);
                crate::obs::record_phase_secs("grid", crate::obs::Phase::SessionRepair, repair);
                Ok((SolveOutcome::Grid(report), name))
            }
            SessionState::Csr { warm, index } => {
                let translated = translate_deltas(index, deltas)?;
                let engine = self.session_fifo(cancel);
                let stats = warm.update(&translated, &engine)?;
                let net = warm.network();
                let report = GridSolveReport {
                    flow: stats.value,
                    excess_total: net
                        .out_edges(net.source())
                        .iter()
                        .map(|&e| net.capacity0(e))
                        .sum(),
                    host_rounds: stats.rounds,
                    pushes: stats.pushes as i64,
                    relabels: stats.relabels as i64,
                    ..Default::default()
                };
                Ok((SolveOutcome::Grid(report), "fifo+global"))
            }
        }
    }

    /// Fresh tiled executor for a session solve, borrowing the worker's
    /// wave pool like the `native-par` backend does.
    fn session_par_exec(&self) -> NativeParGridExecutor {
        let mut exec = NativeParGridExecutor::new(self.cfg.par_threads, self.cfg.tile_rows)
            .with_tuning(self.cfg.tuning);
        if let Some(pool) = &self.wave_pool {
            exec = exec.with_pool(Arc::clone(pool));
        }
        exec
    }

    /// Sequential FIFO engine for CSR sessions, with the worker's wave
    /// pool lent to its periodic global relabel.
    fn session_fifo(&self, cancel: &CancelToken) -> FifoPushRelabel {
        let mut engine = FifoPushRelabel::default()
            .with_striped_min_nodes(self.cfg.striped_relabel_min_nodes)
            .with_cancel(cancel.clone());
        if let Some(pool) = &self.wave_pool {
            engine = engine.with_relabel_pool(Arc::clone(pool));
        }
        engine
    }
}

// ---------------------------------------------------------------------------
// Warm-start sessions: residual caches, LRU store, sticky directory
// ---------------------------------------------------------------------------

/// The residual cache of one open session, shaped by the engine that
/// serves it.
pub(crate) enum SessionState {
    /// Wire-state snapshot for the hybrid wave engines.
    Grid(Box<WarmState>),
    /// CSR residual snapshot for the FIFO engine, with the grid-arc →
    /// edge-id index that translates [`CapacityDelta`]s.
    Csr {
        warm: Box<CsrWarmState>,
        index: GridCsrIndex,
    },
}

impl SessionState {
    fn approx_bytes(&self) -> usize {
        match self {
            SessionState::Grid(warm) => warm.approx_bytes(),
            SessionState::Csr { warm, index } => {
                warm.approx_bytes() + index.height() * index.width() * 24 + 64
            }
        }
    }
}

/// Translate grid-level deltas to CSR edge edits through the index.
fn translate_deltas(index: &GridCsrIndex, deltas: &[CapacityDelta]) -> Result<Vec<CsrDelta>> {
    deltas
        .iter()
        .map(|d| match *d {
            CapacityDelta::Arc { i, j, dir, cap } => {
                ensure!(
                    dir < 4 && i < index.height() && j < index.width(),
                    "delta arc ({i},{j}) dir {dir} off-grid"
                );
                let edge = index
                    .arc(dir, i, j)
                    .ok_or_else(|| anyhow!("delta arc ({i},{j}) dir {dir} leaves the grid"))?;
                Ok(CsrDelta { edge, cap })
            }
            CapacityDelta::Sink { i, j, cap } => {
                ensure!(i < index.height() && j < index.width(), "delta cell off-grid");
                Ok(CsrDelta {
                    edge: index.sink(i, j),
                    cap,
                })
            }
            CapacityDelta::Source { i, j, cap } => {
                ensure!(i < index.height() && j < index.width(), "delta cell off-grid");
                Ok(CsrDelta {
                    edge: index.source(i, j),
                    cap,
                })
            }
        })
        .collect()
}

struct SessionEntry {
    state: SessionState,
    bytes: usize,
    last_used: u64,
}

/// Per-worker LRU of open sessions under a byte budget.  The budget
/// counts the residual caches' approximate resident sizes; the newest
/// session is never evicted by its own insert (a budget smaller than
/// one session would otherwise make sessions unopenable).
pub(crate) struct SessionStore {
    budget_bytes: usize,
    clock: u64,
    bytes: usize,
    entries: HashMap<u64, SessionEntry>,
}

impl SessionStore {
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            budget_bytes,
            clock: 0,
            bytes: 0,
            entries: HashMap::new(),
        }
    }

    /// Insert (or replace) a session, then evict least-recently-used
    /// sessions until the store is back under budget.  Returns the
    /// evicted session ids so the caller can clean the directory.
    pub fn insert(&mut self, id: u64, state: SessionState) -> Vec<u64> {
        if let Some(old) = self.entries.remove(&id) {
            self.bytes -= old.bytes;
        }
        self.clock += 1;
        let bytes = state.approx_bytes();
        self.bytes += bytes;
        self.entries.insert(
            id,
            SessionEntry {
                state,
                bytes,
                last_used: self.clock,
            },
        );
        let mut evicted = Vec::new();
        while self.bytes > self.budget_bytes && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .filter(|(&k, _)| k != id)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
                .expect("len > 1 guarantees a victim");
            let e = self.entries.remove(&victim).unwrap();
            self.bytes -= e.bytes;
            evicted.push(victim);
        }
        evicted
    }

    /// Borrow a session's state, refreshing its recency.
    pub fn get_mut(&mut self, id: u64) -> Option<&mut SessionState> {
        self.clock += 1;
        let clock = self.clock;
        self.entries.get_mut(&id).map(|e| {
            e.last_used = clock;
            &mut e.state
        })
    }

    pub fn remove(&mut self, id: u64) {
        if let Some(e) = self.entries.remove(&id) {
            self.bytes -= e.bytes;
        }
    }

    #[cfg(test)]
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident bytes across every retained session (the LRU budget's
    /// fill level) — read by the per-worker occupancy gauge.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// Pool-global map from session id to the worker holding its residual
/// cache (and the size class it was admitted at).  Submits consult it
/// to route updates sticky; workers prune it as the LRU evicts.
#[derive(Default)]
pub(crate) struct SessionDirectory {
    map: Mutex<HashMap<u64, (usize, SizeClass)>>,
}

impl SessionDirectory {
    pub fn insert(&self, id: u64, worker: usize, class: SizeClass) {
        self.map.lock().unwrap().insert(id, (worker, class));
    }

    pub fn lookup(&self, id: u64) -> Option<(usize, SizeClass)> {
        self.map.lock().unwrap().get(&id).copied()
    }

    /// Live (routable) warm-start sessions across the pool — the
    /// `flowmatch_sessions_live` gauge.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.lock().unwrap().is_empty()
    }

    pub fn remove(&self, id: u64) {
        self.map.lock().unwrap().remove(&id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::hungarian::Hungarian;
    use crate::maxflow::dinic::Dinic;
    use crate::util::Rng;
    use crate::workloads::{random_grid, uniform_costs};

    #[test]
    fn backend_names_roundtrip() {
        for b in [
            AssignBackend::Hungarian,
            AssignBackend::CsaSeq,
            AssignBackend::CsaLockfree,
            AssignBackend::WaveCsa,
        ] {
            assert_eq!(AssignBackend::parse(b.name()).unwrap(), b);
        }
        for b in [
            GridBackend::Native,
            GridBackend::NativePar,
            GridBackend::FifoLockfree,
            GridBackend::Batch,
        ] {
            assert_eq!(GridBackend::parse(b.name()).unwrap(), b);
        }
        assert!(AssignBackend::parse("nope").is_err());
        assert!(GridBackend::parse("nope").is_err());
    }

    #[test]
    fn registry_lists_every_engine_once() {
        let reg = BackendRegistry::standard();
        assert_eq!(
            reg.names(Family::Assignment),
            ["hungarian", "csa-seq", "csa-lockfree", "csa-wave", "pjrt"]
        );
        assert_eq!(
            reg.names(Family::Grid),
            ["native", "native-par", "fifo-lockfree", "grid-batch"]
        );
        // Every static-table name resolves to a registered spec.
        for n in ["hungarian", "csa-seq", "csa-lockfree", "csa-wave"] {
            assert!(reg.names(Family::Assignment).contains(&n));
        }
        for n in ["native", "native-par", "fifo-lockfree", "grid-batch"] {
            assert!(reg.names(Family::Grid).contains(&n));
        }
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn duplicate_registration_rejected() {
        let mut reg = BackendRegistry::standard();
        reg.register("hungarian", Family::Assignment, |_, _| None);
    }

    #[test]
    fn routes_by_class_and_solves_optimally() {
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        let cancel = CancelToken::new();
        let mut rng = Rng::seeded(11);
        let inst = uniform_costs(&mut rng, 12, 50);
        let want = Hungarian.solve(&inst).unwrap().weight;
        for class in SizeClass::ALL {
            let served = backends
                .solve(class, &ProblemInstance::Assignment(inst.clone()), &cancel)
                .unwrap();
            assert_eq!(served.outcome.weight(), Some(want), "class {}", class.name());
            let expected = RouterConfig::default().assign[class.index()].name();
            assert_eq!(served.backend, expected);
            assert_eq!(served.retries, 0);
            assert_eq!(served.breaker_skips, 0);
        }
    }

    #[test]
    fn every_grid_backend_agrees_with_dinic() {
        let mut rng = Rng::seeded(12);
        let net = random_grid(&mut rng, 7, 7, 9, 0.3, 0.3);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap().value;
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        for b in [
            GridBackend::Native,
            GridBackend::NativePar,
            GridBackend::FifoLockfree,
        ] {
            let out = backends
                .solve_named(b.name(), &ProblemInstance::Grid(net.clone()))
                .unwrap();
            assert_eq!(out.flow(), Some(want), "backend {}", b.name());
        }
    }

    /// `batch_max` gates the batched backend: the default config is
    /// bit-identical to the pre-batching registry, and enabling it
    /// instantiates an engine that agrees with Dinic on a batch of one.
    #[test]
    fn grid_batch_backend_is_config_gated_and_optimal() {
        let mut defaults = WorkerBackends::new(RouterConfig::default(), None);
        let mut rng = Rng::seeded(41);
        let net = random_grid(&mut rng, 6, 8, 9, 0.3, 0.3);
        assert!(
            defaults
                .solve_named("grid-batch", &ProblemInstance::Grid(net.clone()))
                .is_err(),
            "grid-batch must not instantiate at batch_max = 1"
        );
        let cfg = RouterConfig {
            batch_max: 8,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, None);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap().value;
        let out = backends
            .solve_named("grid-batch", &ProblemInstance::Grid(net))
            .unwrap();
        assert_eq!(out.flow(), Some(want));
    }

    /// The worker-level batched dispatch returns the same per-slot
    /// reports as routing every instance through `solve` alone.
    #[test]
    fn worker_solve_batch_matches_per_instance_solves() {
        let cfg = RouterConfig {
            batch_max: 8,
            ..RouterConfig::default()
        };
        let instances: Vec<ProblemInstance> = [(42u64, 5, 7), (43, 7, 5), (44, 7, 7)]
            .iter()
            .map(|&(seed, h, w)| {
                let mut rng = Rng::seeded(seed);
                ProblemInstance::Grid(random_grid(&mut rng, h, w, 9, 0.3, 0.3))
            })
            .collect();
        let cancels: Vec<CancelToken> = instances.iter().map(|_| CancelToken::new()).collect();
        let mut batched = WorkerBackends::new(cfg.clone(), None);
        let got = batched
            .solve_batch(SizeClass::Small, &instances, &cancels)
            .expect("grid-batch available and batch non-trivial");
        assert_eq!(got.len(), instances.len());
        let mut solo = WorkerBackends::new(cfg, None);
        for (k, (inst, served)) in instances.iter().zip(got).enumerate() {
            let served = served.unwrap_or_else(|e| panic!("slot {k}: {e}"));
            assert_eq!(served.backend, "grid-batch", "slot {k}");
            let want = solo
                .solve_named("grid-batch", inst)
                .unwrap()
                .flow()
                .unwrap();
            assert_eq!(served.outcome.flow(), Some(want), "slot {k}");
        }
        // Singleton batches decline so the caller takes the ordinary
        // per-instance path (no joint-dispatch overhead for one job).
        assert!(batched
            .solve_batch(SizeClass::Small, &instances[..1], &cancels[..1])
            .is_none());
    }

    /// An already-expired slot in a batch surfaces as a cancelled
    /// failure while its batchmates solve to optimality.
    #[test]
    fn expired_slot_in_worker_batch_is_cancelled_not_failed() {
        let cfg = RouterConfig {
            batch_max: 8,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, None);
        let instances: Vec<ProblemInstance> = [(45u64, 6, 6), (46, 6, 6)]
            .iter()
            .map(|&(seed, h, w)| {
                let mut rng = Rng::seeded(seed);
                ProblemInstance::Grid(random_grid(&mut rng, h, w, 9, 0.3, 0.3))
            })
            .collect();
        let dead = CancelToken::new();
        dead.cancel();
        let cancels = vec![CancelToken::new(), dead];
        let got = backends
            .solve_batch(SizeClass::Small, &instances, &cancels)
            .unwrap();
        assert!(got[0].is_ok(), "live slot must solve");
        match &got[1] {
            Err(f) => assert!(f.cancelled, "expired slot must be a deadline miss"),
            Ok(_) => panic!("expired slot must not solve"),
        }
    }

    #[test]
    fn backend_rejects_wrong_family() {
        let mut backends = WorkerBackends::new(RouterConfig::default(), None);
        let mut rng = Rng::seeded(13);
        let net = random_grid(&mut rng, 4, 4, 5, 0.3, 0.3);
        let err = backends
            .solve_named("hungarian", &ProblemInstance::Grid(net))
            .unwrap_err();
        assert!(err.to_string().contains("cannot serve"), "{err}");
    }

    #[test]
    fn adaptive_cold_start_covers_all_assignment_engines() {
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, None);
        let mut rng = Rng::seeded(14);
        let inst = uniform_costs(&mut rng, 10, 40);
        let want = Hungarian.solve(&inst).unwrap().weight;
        let mut seen = std::collections::BTreeSet::new();
        let cancel = CancelToken::new();
        for _ in 0..4 {
            let served = backends
                .solve(
                    SizeClass::Small,
                    &ProblemInstance::Assignment(inst.clone()),
                    &cancel,
                )
                .unwrap();
            assert_eq!(
                served.outcome.weight(),
                Some(want),
                "backend {} suboptimal",
                served.backend
            );
            seen.insert(served.backend);
        }
        // use_pjrt = false → exactly the four native engines, each
        // probed once during cold start.
        assert_eq!(
            seen.into_iter().collect::<Vec<_>>(),
            ["csa-lockfree", "csa-seq", "csa-wave", "hungarian"]
        );
    }

    struct AlwaysFails;

    impl Backend for AlwaysFails {
        fn name(&self) -> &'static str {
            "always-fails"
        }

        fn family(&self) -> Family {
            Family::Assignment
        }

        fn solve(&mut self, _: &ProblemInstance, _: &CancelToken) -> Result<SolveOutcome> {
            bail!("injected failure")
        }
    }

    fn broken_plus_hungarian() -> BackendRegistry {
        let mut reg = BackendRegistry::new();
        reg.register("always-fails", Family::Assignment, |_, _| {
            Some(Box::new(AlwaysFails))
        });
        reg.register("hungarian", Family::Assignment, |_, _| {
            Some(Box::new(HungarianBackend))
        });
        reg
    }

    /// A backend whose every solve errors must still get measured (with
    /// the failure penalty) — otherwise adaptive cold start, which
    /// prefers unmeasured candidates, would re-select it forever.
    /// `max_retries = 0` isolates the routing behaviour from the retry
    /// machinery (which would otherwise mask the first failure).
    #[test]
    fn failing_backend_is_demoted_not_repinned() {
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            max_retries: 0,
            breaker_threshold: 0,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::with_registry_for_tests(cfg, &broken_plus_hungarian());
        let cancel = CancelToken::new();
        let mut rng = Rng::seeded(16);
        let inst = ProblemInstance::Assignment(uniform_costs(&mut rng, 6, 20));
        // Cold start hits the broken engine first; the error propagates.
        let err = backends.solve(SizeClass::Small, &inst, &cancel).unwrap_err();
        assert!(err.error.contains("injected failure"), "{}", err.error);
        assert!(!err.cancelled);
        // But the failure was recorded (penalised), so the router cold
        // starts the healthy engine next and then keeps winning with it
        // instead of re-pinning the broken one.
        for _ in 0..3 {
            let served = backends.solve(SizeClass::Small, &inst, &cancel).unwrap();
            assert_eq!(served.backend, "hungarian");
        }
    }

    /// Retry-with-fallback: the first attempt lands on the broken
    /// engine (adaptive cold start, registration order), the retry goes
    /// to the next *different* backend and succeeds.
    #[test]
    fn retry_falls_back_to_next_backend() {
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            max_retries: 2,
            retry_backoff_ms: 0,
            breaker_threshold: 0,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::with_registry_for_tests(cfg, &broken_plus_hungarian());
        let mut rng = Rng::seeded(17);
        let raw = uniform_costs(&mut rng, 6, 20);
        let want = Hungarian.solve(&raw).unwrap().weight;
        let inst = ProblemInstance::Assignment(raw);
        let served = backends
            .solve(SizeClass::Small, &inst, &CancelToken::new())
            .unwrap();
        assert_eq!(served.backend, "hungarian");
        assert_eq!(served.retries, 1, "exactly one retry");
        assert_eq!(served.outcome.weight(), Some(want));
    }

    /// Circuit breaker: after `breaker_threshold` consecutive failures
    /// the broken engine's breaker opens and the router stops offering
    /// it first attempts — requests go straight to the fallback with no
    /// retries, and the skip is counted.
    #[test]
    fn breaker_opens_and_routes_around() {
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            max_retries: 1,
            retry_backoff_ms: 0,
            breaker_threshold: 2,
            breaker_cooldown: 100, // stays open for the whole test
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::with_registry_for_tests(cfg, &broken_plus_hungarian());
        let cancel = CancelToken::new();
        let mut rng = Rng::seeded(18);
        let inst = ProblemInstance::Assignment(uniform_costs(&mut rng, 6, 20));
        // Two requests fail over to hungarian, each charging the broken
        // engine one breaker strike...
        for _ in 0..2 {
            let served = backends.solve(SizeClass::Small, &inst, &cancel).unwrap();
            assert_eq!(served.backend, "hungarian");
            assert_eq!(served.retries, 1);
        }
        assert!(!backends.telemetry().breaker_allows(
            Family::Assignment,
            SizeClass::Small,
            "always-fails"
        ));
        // ...after which the open breaker is routed around up front.
        let served = backends.solve(SizeClass::Small, &inst, &cancel).unwrap();
        assert_eq!(served.backend, "hungarian");
        assert_eq!(served.retries, 0, "no retry needed once the breaker is open");
        assert!(served.breaker_skips >= 1);
        let snap = backends.telemetry().breaker_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].state, "open");
        assert_eq!(snap[0].backend, "always-fails");
    }

    /// A panicking backend is a failed attempt, not a dead worker: the
    /// panic is caught, penalised, and retried on the fallback.
    struct AlwaysPanics;

    impl Backend for AlwaysPanics {
        fn name(&self) -> &'static str {
            "always-panics"
        }

        fn family(&self) -> Family {
            Family::Assignment
        }

        fn solve(&mut self, _: &ProblemInstance, _: &CancelToken) -> Result<SolveOutcome> {
            panic!("injected panic")
        }
    }

    #[test]
    fn panicking_backend_is_caught_and_retried() {
        let mut reg = BackendRegistry::new();
        reg.register("always-panics", Family::Assignment, |_, _| {
            Some(Box::new(AlwaysPanics))
        });
        reg.register("hungarian", Family::Assignment, |_, _| {
            Some(Box::new(HungarianBackend))
        });
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            max_retries: 1,
            retry_backoff_ms: 0,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::with_registry_for_tests(cfg, &reg);
        let mut rng = Rng::seeded(19);
        let inst = ProblemInstance::Assignment(uniform_costs(&mut rng, 6, 20));
        let served = backends
            .solve(SizeClass::Small, &inst, &CancelToken::new())
            .unwrap();
        assert_eq!(served.backend, "hungarian");
        assert_eq!(served.retries, 1);

        // With retries off, the panic surfaces as a failure message.
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            max_retries: 0,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::with_registry_for_tests(cfg, &reg);
        let err = backends
            .solve(SizeClass::Small, &inst, &CancelToken::new())
            .unwrap_err();
        assert!(err.error.contains("injected panic"), "{}", err.error);
    }

    /// A pre-expired deadline cancels instead of failing: no retry, no
    /// breaker strike, and the failure is marked `cancelled`.
    #[test]
    fn cancelled_solve_is_not_retried() {
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            probe_every: 0,
            max_retries: 2,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, None);
        let expired =
            CancelToken::with_deadline(Some(Instant::now() - std::time::Duration::from_millis(1)));
        let mut rng = Rng::seeded(20);
        let inst = ProblemInstance::Assignment(uniform_costs(&mut rng, 6, 20));
        let err = backends.solve(SizeClass::Small, &inst, &expired).unwrap_err();
        assert!(err.cancelled, "{}", err.error);
        assert_eq!(err.retries, 0, "cancellation must not burn retries");
        assert_eq!(backends.telemetry().breaker_snapshot().len(), 0);
    }

    /// The chaos wrapper sits inside the registry: a `FaultPlan`
    /// targeting a backend makes exactly that backend misbehave on
    /// schedule, and the retry path absorbs it.
    #[test]
    fn fault_plan_wraps_target_in_registry() {
        let cfg = RouterConfig {
            max_retries: 1,
            retry_backoff_ms: 0,
            fault: Some(FaultPlan::new("native").with_fail_every(1)),
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, None);
        let mut rng = Rng::seeded(21);
        let net = random_grid(&mut rng, 6, 6, 5, 0.3, 0.3);
        // Static Small grid route is "native" — every solve fails, so
        // the retry lands on the next grid backend.
        let served = backends
            .solve(SizeClass::Small, &ProblemInstance::Grid(net), &CancelToken::new())
            .unwrap();
        assert_eq!(served.retries, 1);
        assert_ne!(served.backend, "native");
    }

    /// Saturation spill: with the shared wave pool's queue backed up
    /// past `spill_depth`, a Large grid solve is re-routed to the
    /// self-threaded `fifo-lockfree` engine — and the flow value is
    /// unchanged.
    #[test]
    fn large_grid_spills_to_lockfree_when_pool_saturated() {
        use std::sync::{Condvar, Mutex};

        let pool = Arc::new(WorkerPool::new(1));
        let cfg = RouterConfig {
            routing: RoutingMode::Adaptive,
            spill_depth: 2,
            par_threads: 1,
            ..RouterConfig::default()
        };
        let mut backends = WorkerBackends::new(cfg, Some(&pool));

        let mut rng = Rng::seeded(15);
        let net = random_grid(&mut rng, 8, 8, 9, 0.3, 0.3);
        let mut g = net.to_flow_network();
        let want = Dinic.solve(&mut g).unwrap().value;

        // Saturate the 1-thread wave pool: the worker blocks on the
        // gate, two more jobs sit queued → pending() == 2 == spill_depth.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let blocked = {
            let pool = Arc::clone(&pool);
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || {
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                    .map(|_| {
                        let gate = Arc::clone(&gate);
                        Box::new(move || {
                            let (lock, cv) = &*gate;
                            let mut open = lock.lock().unwrap();
                            while !*open {
                                open = cv.wait(open).unwrap();
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.scope_run(jobs);
            })
        };
        while pool.pending() < 2 {
            std::thread::yield_now();
        }

        let served = backends
            .solve(
                SizeClass::Large,
                &ProblemInstance::Grid(net.clone()),
                &CancelToken::new(),
            )
            .unwrap();
        assert_eq!(served.backend, "fifo-lockfree", "saturated pool must spill");
        assert_eq!(
            served.outcome.flow(),
            Some(want),
            "spilled solve changed the flow"
        );

        // Open the gate; once the pool drains, Large grids route
        // normally again (cold start: first un-measured grid engine).
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        blocked.join().unwrap();
        assert_eq!(pool.pending(), 0);
        let served = backends
            .solve(SizeClass::Large, &ProblemInstance::Grid(net), &CancelToken::new())
            .unwrap();
        assert_ne!(served.backend, "fifo-lockfree", "drained pool must not spill");
    }
}
