//! The sharded solver-pool service: one persistent runtime that serves
//! **both** paper algorithms — grid max-flow (§4) and assignment (§5) —
//! behind a single submit/reply API, built for the §6 real-time claim
//! ("about 1/20 s, which allows for real-time applications") under
//! mixed load.
//!
//! Layers:
//!
//! * [`pool`] — the persistent workers: a scoped-job [`WorkerPool`]
//!   (borrowed by the tiled wave engine instead of per-wave thread
//!   spawns) and the request-serving [`SolverPool`].
//! * [`shard`] — size-class sharded queues with admission control and
//!   reject-with-reason backpressure, so small real-time matchings
//!   never sit behind 512² grid solves.
//! * [`router`] — the [`Backend`] trait + [`BackendRegistry`]: every
//!   engine (hungarian / csa-seq / csa-lockfree / csa-wave / PJRT for
//!   assignment; native / native-par / fifo-lockfree for grids) is
//!   registered once and instantiated per worker, with solver scratch
//!   and artifact caches surviving across requests.
//! * [`adaptive`] — measurement-driven routing: per-(family ×
//!   size-class × backend) latency EWMAs in a shared [`TelemetrySink`],
//!   deterministic ε-greedy probing, route-to-winner steady state, and
//!   saturation spill of Large grid solves to `fifo-lockfree` when the
//!   wave pool's queue backs up.  Static (PR 3 tables) stays the
//!   default; select with `[service] routing = "adaptive"`.
//! * [`loadgen`] — mixed-trace replay (open- and closed-loop) with
//!   p50/p95/p99/max latency, throughput, and reject-reason reporting,
//!   plus the spawn-per-request baseline the pool replaces.
//!
//! The legacy assignment-only `coordinator::server::AssignmentService`
//! is now a thin shim over [`SolverPool`].

pub mod adaptive;
pub mod fault;
pub mod loadgen;
pub mod pool;
pub mod router;
pub mod shard;

use std::fmt;

use anyhow::Result;

use crate::assignment::AssignmentResult;
use crate::config::Config;
use crate::gridflow::GridSolveReport;

pub use crate::gridflow::HostRounds;
pub use crate::util::{CancelToken, Cancelled};
pub use crate::workloads::ProblemInstance;
pub use adaptive::{BreakerStat, RouteStat, RoutingMode, TelemetrySink};
pub use fault::{backoff_delay, FaultPlan, FaultyBackend};
pub use loadgen::{
    replay, replay_sessions, replay_spawn_baseline, ReplayError, ReplayOutcome,
    SessionReplayOutcome,
};
pub use pool::{PoolReport, SolverPool, WorkerPool};
pub use router::{AssignBackend, Backend, BackendRegistry, Family, GridBackend, RouterConfig};
pub use shard::{RejectReason, ShardConfig, SizeClass};

/// What a request solved to, by family.
#[derive(Debug, Clone)]
pub enum SolveOutcome {
    Assignment(AssignmentResult),
    Grid(GridSolveReport),
}

impl SolveOutcome {
    pub fn family(&self) -> &'static str {
        match self {
            SolveOutcome::Assignment(_) => "assignment",
            SolveOutcome::Grid(_) => "grid",
        }
    }

    /// Matching weight, for assignment outcomes.
    pub fn weight(&self) -> Option<i64> {
        match self {
            SolveOutcome::Assignment(r) => Some(r.weight),
            SolveOutcome::Grid(_) => None,
        }
    }

    /// Max-flow value, for grid outcomes.
    pub fn flow(&self) -> Option<i64> {
        match self {
            SolveOutcome::Assignment(_) => None,
            SolveOutcome::Grid(r) => Some(r.flow),
        }
    }

    pub fn assignment(&self) -> Option<&AssignmentResult> {
        match self {
            SolveOutcome::Assignment(r) => Some(r),
            SolveOutcome::Grid(_) => None,
        }
    }

    pub fn grid(&self) -> Option<&GridSolveReport> {
        match self {
            SolveOutcome::Assignment(_) => None,
            SolveOutcome::Grid(r) => Some(r),
        }
    }
}

/// Why a submitted request produced no successful reply.  This is the
/// typed error side of the reply channel (PR 6; previously a bare
/// `String`), so clients can distinguish shed load from solve failures
/// without re-parsing messages.
#[derive(Debug, Clone)]
pub enum ReplyError {
    /// Shed before solving: admission control or a pre-dispatch
    /// deadline miss ([`RejectReason::DeadlineExceeded`]).
    Rejected(RejectReason),
    /// Every attempt failed (after `retries` retries), or the solve
    /// was cancelled mid-flight by its deadline.
    Failed { message: String, retries: u32 },
    /// The reply channel closed without a reply — the invariant the
    /// fault tests assert never happens (a worker died mid-request).
    Lost,
    /// A session update addressed a warm-start session the pool no
    /// longer holds (LRU-evicted under the memory budget, dropped
    /// after a failed update, or never opened).  The client falls back
    /// to a cold solve of its edited graph.
    SessionEvicted,
}

impl fmt::Display for ReplyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplyError::Rejected(r) => write!(f, "rejected: {r}"),
            ReplyError::Failed { message, retries } => {
                if *retries > 0 {
                    write!(f, "{message} (after {retries} retries)")
                } else {
                    write!(f, "{message}")
                }
            }
            ReplyError::Lost => write!(f, "service dropped the reply"),
            ReplyError::SessionEvicted => {
                write!(f, "session evicted: resubmit the edited graph cold")
            }
        }
    }
}

impl std::error::Error for ReplyError {}

/// One reply from the pool.
#[derive(Debug, Clone)]
pub struct SolveReply {
    pub id: u64,
    pub class: SizeClass,
    /// Index of the solver worker that served the request
    /// (`usize::MAX` for the spawn-baseline path).
    pub worker: usize,
    /// Backend that actually served it (e.g. "hungarian", "pjrt",
    /// "native-par").
    pub backend: &'static str,
    /// Seconds from submit to completion.
    pub latency: f64,
    /// Seconds spent queued before a worker picked the request up.
    pub queue_delay: f64,
    /// Failed attempts absorbed before this reply (fallback retries).
    pub retries: u32,
    /// Open circuit breakers routed around while placing the request.
    pub breaker_skips: u32,
    /// Warm-start session this reply belongs to: `Some(id)` when the
    /// request opened a session or updated one.
    pub session: Option<u64>,
    /// True when the reply came from an incremental (delta) solve of a
    /// retained residual cache rather than a cold solve.
    pub warm: bool,
    /// Per-phase breakdown of this solve: queue wait plus the engine's
    /// own phase timings for grid solves.  `None` from paths that don't
    /// trace (the spawn baseline, rejected requests).
    pub phases: Option<crate::obs::PhaseBreakdown>,
    pub outcome: SolveOutcome,
}

/// Full pool configuration: worker count + sharding + routing.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    pub workers: usize,
    pub shard: ShardConfig,
    pub router: RouterConfig,
    /// Per-worker memory budget for retained warm-start session state,
    /// in MiB; the least-recently-used session is evicted when a new
    /// one would exceed it.
    pub session_budget_mb: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            shard: ShardConfig::default(),
            router: RouterConfig::default(),
            session_budget_mb: 64,
        }
    }
}

impl PoolConfig {
    /// Read `[service]` keys from a config (preset or file), falling
    /// back to the defaults for anything missing.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = PoolConfig::default();
        let mut out = PoolConfig {
            workers: cfg.get_usize("service.workers", d.workers)?,
            session_budget_mb: cfg
                .get_usize("service.session_budget_mb", d.session_budget_mb)?,
            shard: ShardConfig {
                small_max_units: cfg
                    .get_usize("service.small_units", d.shard.small_max_units)?,
                medium_max_units: cfg
                    .get_usize("service.medium_units", d.shard.medium_max_units)?,
                queue_depth: cfg.get_usize("service.queue_depth", d.shard.queue_depth)?,
                max_units: cfg.get_usize("service.max_units", d.shard.max_units)?,
            },
            router: RouterConfig {
                use_pjrt: cfg.get_bool("service.use_pjrt", d.router.use_pjrt)?,
                pjrt_max_n: cfg.get_usize("service.pjrt_max_n", d.router.pjrt_max_n)?,
                alpha: cfg.get_i64("service.alpha", d.router.alpha)?,
                csa_threads: cfg.get_usize("service.csa_threads", d.router.csa_threads)?,
                cycle_waves: cfg.get_usize("service.cycle", d.router.cycle_waves)?,
                par_threads: cfg.get_usize("service.threads", d.router.par_threads)?,
                tile_rows: cfg.get_usize("service.tile_rows", d.router.tile_rows)?,
                // Shared key with the coordinator path: one switch
                // flips host rounds everywhere a hybrid solver runs.
                host_rounds: match cfg.get("gridflow.host_rounds") {
                    Some(name) => crate::gridflow::HostRounds::parse(name)?,
                    None => d.router.host_rounds,
                },
                tuning: crate::parallel::ParTuning {
                    balance: match cfg.get("gridflow.stripe_balance") {
                        Some(name) => crate::parallel::StripeBalance::parse(name)?,
                        None => d.router.tuning.balance,
                    },
                    commit: match cfg.get("gridflow.commit") {
                        Some(name) => crate::parallel::CommitMode::parse(name)?,
                        None => d.router.tuning.commit,
                    },
                },
                striped_relabel_min_nodes: cfg.get_usize(
                    "maxflow.striped_relabel_min_nodes",
                    d.router.striped_relabel_min_nodes,
                )?,
                routing: match cfg.get("service.routing") {
                    Some(name) => RoutingMode::parse(name)?,
                    None => d.router.routing,
                },
                probe_every: cfg.get_usize("service.probe_every", d.router.probe_every)?,
                spill_depth: cfg.get_usize("service.spill_depth", d.router.spill_depth)?,
                max_retries: cfg.get_usize("service.max_retries", d.router.max_retries as usize)?
                    as u32,
                retry_backoff_ms: cfg.get_usize(
                    "service.retry_backoff_ms",
                    d.router.retry_backoff_ms as usize,
                )? as u64,
                breaker_threshold: cfg
                    .get_usize("service.breaker_threshold", d.router.breaker_threshold)?,
                breaker_cooldown: cfg
                    .get_usize("service.breaker_cooldown", d.router.breaker_cooldown)?,
                batch_max: cfg.get_usize("service.batch_max", d.router.batch_max)?,
                batch_linger_us: cfg.get_usize(
                    "service.batch_linger_us",
                    d.router.batch_linger_us as usize,
                )? as u64,
                ..d.router
            },
        };
        for (i, key) in ["assign_small", "assign_medium", "assign_large"]
            .iter()
            .enumerate()
        {
            if let Some(name) = cfg.get(&format!("service.{key}")) {
                out.router.assign[i] = AssignBackend::parse(name)?;
            }
        }
        for (i, key) in ["grid_small", "grid_medium", "grid_large"].iter().enumerate() {
            if let Some(name) = cfg.get(&format!("service.{key}")) {
                out.router.grid[i] = GridBackend::parse(name)?;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_config_from_preset_config() {
        let cfg = Config::parse(
            "[service]\nworkers = 3\nqueue_depth = 8\nsmall_units = 100\n\
             medium_units = 1000\nmax_units = 5000\nassign_medium = \"csa-seq\"\n\
             grid_large = \"fifo-lockfree\"\ncycle = 99\nthreads = 2\n",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.workers, 3);
        assert_eq!(pc.shard.queue_depth, 8);
        assert_eq!(pc.shard.small_max_units, 100);
        assert_eq!(pc.shard.max_units, 5000);
        assert_eq!(pc.router.assign[1], AssignBackend::CsaSeq);
        assert_eq!(pc.router.assign[0], AssignBackend::Hungarian);
        assert_eq!(pc.router.grid[2], GridBackend::FifoLockfree);
        assert_eq!(pc.router.cycle_waves, 99);
        assert_eq!(pc.router.par_threads, 2);
    }

    #[test]
    fn tuning_keys_from_config() {
        use crate::parallel::{CommitMode, StripeBalance};
        let cfg = Config::parse(
            "[gridflow]\nstripe_balance = \"weighted\"\ncommit = \"merged\"\n\
             [maxflow]\nstriped_relabel_min_nodes = 64\n",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.router.tuning.balance, StripeBalance::Weighted);
        assert_eq!(pc.router.tuning.commit, CommitMode::Merged);
        assert_eq!(pc.router.striped_relabel_min_nodes, 64);
        // Absent keys keep the bit-exact defaults.
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc.router.tuning.balance, StripeBalance::Fixed);
        assert_eq!(pc.router.tuning.commit, CommitMode::TwoPass);
        assert_eq!(
            pc.router.striped_relabel_min_nodes,
            crate::maxflow::global_relabel::STRIPED_RELABEL_MIN_NODES
        );
        let bad = Config::parse("[gridflow]\nstripe_balance = \"nope\"\n").unwrap();
        assert!(PoolConfig::from_config(&bad).is_err());
        let bad = Config::parse("[gridflow]\ncommit = \"nope\"\n").unwrap();
        assert!(PoolConfig::from_config(&bad).is_err());
    }

    #[test]
    fn bad_backend_name_rejected() {
        let cfg = Config::parse("[service]\nassign_small = \"nope\"\n").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn host_rounds_key_from_config() {
        let cfg = Config::parse("[gridflow]\nhost_rounds = \"striped\"\n").unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.router.host_rounds, HostRounds::Striped);
        // Absent key keeps the bit-exact sequential default.
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc.router.host_rounds, HostRounds::Seq);
        let bad = Config::parse("[gridflow]\nhost_rounds = \"nope\"\n").unwrap();
        assert!(PoolConfig::from_config(&bad).is_err());
    }

    #[test]
    fn routing_keys_from_config() {
        let cfg = Config::parse(
            "[service]\nrouting = \"adaptive\"\nprobe_every = 5\nspill_depth = 3\n",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.router.routing, RoutingMode::Adaptive);
        assert_eq!(pc.router.probe_every, 5);
        assert_eq!(pc.router.spill_depth, 3);
        // Absent keys keep the static default.
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc.router.routing, RoutingMode::Static);
        let bad = Config::parse("[service]\nrouting = \"nope\"\n").unwrap();
        assert!(PoolConfig::from_config(&bad).is_err());
    }

    #[test]
    fn fault_tolerance_keys_from_config() {
        let cfg = Config::parse(
            "[service]\nmax_retries = 5\nretry_backoff_ms = 9\n\
             breaker_threshold = 4\nbreaker_cooldown = 12\n",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.router.max_retries, 5);
        assert_eq!(pc.router.retry_backoff_ms, 9);
        assert_eq!(pc.router.breaker_threshold, 4);
        assert_eq!(pc.router.breaker_cooldown, 12);
        // Absent keys keep the defaults; no fault plan unless injected.
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc.router.max_retries, 2);
        assert_eq!(pc.router.breaker_threshold, 3);
        assert!(pc.router.fault.is_none());
    }

    #[test]
    fn batching_keys_from_config() {
        let cfg = Config::parse("[service]\nbatch_max = 8\nbatch_linger_us = 450\n").unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.router.batch_max, 8);
        assert_eq!(pc.router.batch_linger_us, 450);
        // Absent keys keep batching off: batch_max = 1 means the
        // grid-batch backend never instantiates and the shard queues
        // never cut batches.
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc.router.batch_max, 1);
        assert_eq!(pc.router.batch_linger_us, 200);
        // The batched backend is routable through the static table.
        assert_eq!(GridBackend::parse("grid-batch").unwrap(), GridBackend::Batch);
    }

    #[test]
    fn reply_error_renders() {
        let rejected = ReplyError::Rejected(RejectReason::TooLarge {
            units: 9,
            max_units: 4,
        });
        assert!(rejected.to_string().contains("too large"));
        let failed = ReplyError::Failed {
            message: "solver error: boom".into(),
            retries: 2,
        };
        assert!(failed.to_string().contains("after 2 retries"));
        assert!(ReplyError::Lost.to_string().contains("dropped"));
        assert!(ReplyError::SessionEvicted.to_string().contains("session evicted"));
    }

    #[test]
    fn session_budget_from_config() {
        let cfg = Config::parse("[service]\nsession_budget_mb = 7\n").unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.session_budget_mb, 7);
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc.session_budget_mb, 64);
    }

    #[test]
    fn outcome_accessors() {
        let g = SolveOutcome::Grid(GridSolveReport {
            flow: 7,
            ..Default::default()
        });
        assert_eq!(g.flow(), Some(7));
        assert_eq!(g.weight(), None);
        assert_eq!(g.family(), "grid");
        assert!(g.grid().is_some() && g.assignment().is_none());
    }
}
