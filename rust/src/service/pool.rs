//! The persistent worker pools.
//!
//! Two pools live here:
//!
//! * [`WorkerPool`] — N long-lived OS threads parked on a condvar that
//!   execute batches of *scoped* jobs (closures borrowing the caller's
//!   stack).  This is the engine-room primitive: the tiled wave engine
//!   (`gridflow::par_wave`) borrows it instead of spawning two rounds
//!   of scoped threads per wave, which retires the per-wave spawn
//!   overhead the ROADMAP flagged.
//! * [`SolverPool`] — the request-serving runtime: N long-lived solver
//!   workers pull [`QueuedJob`]s from the size-class sharded queues
//!   ([`super::shard`]), route them to a backend ([`super::router`],
//!   with per-worker solver/artifact caches), and reply over the
//!   per-request channel.  No thread is ever spawned per request.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::gridflow::CapacityDelta;
use crate::obs::{self, Phase, PhaseBreakdown};
use crate::util::stats::{LatencyRecorder, Summary};
use crate::util::{CancelToken, Cancelled};
use crate::workloads::ProblemInstance;

use super::adaptive::{BreakerStat, RouteStat, TelemetrySink};
use super::router::{RouterConfig, SessionDirectory, SessionStore, WorkerBackends};
use super::shard::{JobPayload, QueuedJob, RejectReason, ShardedQueues, SizeClass};
use super::{PoolConfig, ReplyError, SolveReply};

// ---------------------------------------------------------------------------
// WorkerPool: persistent threads executing scoped job batches
// ---------------------------------------------------------------------------

type StaticJob = Box<dyn FnOnce() + Send + 'static>;

/// Completion latch for one `scope_run` batch.
struct Latch {
    state: Mutex<(usize, usize)>, // (remaining, panicked)
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new((n, 0)),
            cv: Condvar::new(),
        })
    }

    fn complete(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if panicked {
            st.1 += 1;
        }
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Wait for every job; returns how many panicked.
    fn wait(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.1
    }
}

struct PoolQueue {
    jobs: VecDeque<(StaticJob, Arc<Latch>)>,
    shutdown: bool,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    work_cv: Condvar,
    /// Worker threads respawned after dying mid-job (a panic that
    /// escaped the per-job catch, e.g. a panic payload whose `Drop`
    /// panics).  Capacity self-heals instead of silently shrinking.
    respawns: AtomicU64,
}

/// A fixed set of long-lived worker threads that run scoped job
/// batches.  Threads park on a condvar between batches, so handing a
/// wave's two phases to the pool costs two wakeups instead of two
/// rounds of `thread::spawn`.
///
/// Concurrent `scope_run` calls from different threads are safe (each
/// batch has its own completion latch); a job must never call
/// `scope_run` on the pool it runs on (it would deadlock waiting for a
/// worker slot it occupies).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Engines that optionally borrow a pool derive Debug; the
        // interesting facts are its width and current backlog.
        f.debug_struct("WorkerPool")
            .field("threads", &self.workers.len())
            .field("pending", &self.pending())
            .finish()
    }
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            respawns: AtomicU64::new(0),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("flowmatch-pool-{i}"))
                    .spawn(move || pool_worker_loop(shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Jobs queued but not yet picked up by a pool thread — the
    /// saturation signal the adaptive router's spill check reads.  A
    /// non-zero depth means tile phases handed to the pool right now
    /// would wait behind other solves' work.
    pub fn pending(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Worker threads respawned after dying mid-job.
    pub fn respawns(&self) -> u64 {
        self.shared.respawns.load(Ordering::SeqCst)
    }

    /// Run every job to completion on the pool, blocking until all are
    /// done; returns how many panicked instead of panicking the caller.
    /// This is the service-path entry point: one bad tile job becomes a
    /// reportable error, not a dead request worker.
    ///
    /// The jobs may borrow from the caller's stack (`'env`): the
    /// lifetime erasure below is sound because this function does not
    /// return until every job has finished executing, so no borrow
    /// escapes the frame that owns it — the same contract
    /// `std::thread::scope` enforces.
    pub fn try_run_batch<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) -> usize {
        if jobs.is_empty() {
            return 0;
        }
        let latch = Latch::new(jobs.len());
        {
            let mut q = self.shared.queue.lock().unwrap();
            assert!(!q.shutdown, "batch run on a shut-down WorkerPool");
            for job in jobs {
                // SAFETY: `latch.wait()` below blocks until this job has
                // run to completion (or panicked), so the 'env borrows
                // inside it cannot outlive this call.
                let job: StaticJob = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, StaticJob>(job)
                };
                q.jobs.push_back((job, Arc::clone(&latch)));
            }
        }
        self.shared.work_cv.notify_all();
        latch.wait()
    }

    /// [`WorkerPool::try_run_batch`] with the legacy contract:
    /// propagates a panic if any job panicked.
    pub fn scope_run<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let panicked = self.try_run_batch(jobs);
        if panicked > 0 {
            panic!("{panicked} WorkerPool job(s) panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Replaces a pool worker that dies mid-loop.  The per-job
/// `catch_unwind` absorbs ordinary job panics, but a hostile panic
/// *payload* (one whose `Drop` itself panics) still unwinds the worker
/// thread — without this guard the pool's capacity would silently
/// shrink by one thread per such incident.
struct RespawnGuard {
    shared: Arc<PoolShared>,
}

impl Drop for RespawnGuard {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return; // normal shutdown exit
        }
        // Poisoned lock or shutdown in progress: nothing to revive.
        let shutting_down = self
            .shared
            .queue
            .lock()
            .map(|q| q.shutdown)
            .unwrap_or(true);
        if shutting_down {
            return;
        }
        let n = self.shared.respawns.fetch_add(1, Ordering::SeqCst);
        crate::log_warn!("wave-pool worker died mid-job (hostile panic); respawning (total {})", n + 1);
        let shared = Arc::clone(&self.shared);
        // Detached: it exits via the shutdown flag like any worker.
        let _ = std::thread::Builder::new()
            .name(format!("flowmatch-pool-respawn-{n}"))
            .spawn(move || pool_worker_loop(shared));
    }
}

fn pool_worker_loop(shared: Arc<PoolShared>) {
    let _guard = RespawnGuard {
        shared: Arc::clone(&shared),
    };
    loop {
        let (job, latch) = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(item) = q.jobs.pop_front() {
                    break item;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        // Complete the latch before dropping the panic payload: even a
        // payload whose Drop panics (killing this thread) cannot leave
        // the batch's caller blocked.
        latch.complete(outcome.is_err());
    }
}

// ---------------------------------------------------------------------------
// SolverPool: the sharded request-serving runtime
// ---------------------------------------------------------------------------

/// One label per started pool (`pool="p0"`, `pool="p1"`, …) so
/// concurrently running pools — parallel tests, the chaos harness —
/// never alias each other's series in the global metrics registry.
static POOL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Pre-registered registry twins for the [`PoolMetrics`] fields.  Every
/// local field mutation bumps the matching `flowmatch_pool_*` series at
/// the same call site, so the live exposition endpoint and the shutdown
/// [`PoolReport`] can never disagree (`tests/integration_metrics.rs`
/// holds them equal).
struct MetricTwins {
    label: String,
    served: Arc<obs::Counter>,
    rejected: Arc<obs::Counter>,
    failed: Arc<obs::Counter>,
    retries: Arc<obs::Counter>,
    breaker_skips: Arc<obs::Counter>,
    deadline_misses: Arc<obs::Counter>,
    warm_served: Arc<obs::Counter>,
    sessions_evicted: Arc<obs::Counter>,
    batches: Arc<obs::Counter>,
    batched_jobs: Arc<obs::Counter>,
    padding_waste_cells: Arc<obs::Counter>,
    linger_sheds: Arc<obs::Counter>,
    latency: Arc<obs::Histogram>,
}

impl MetricTwins {
    fn new(label: &str) -> Self {
        let reg = obs::global();
        let c = |field: &str| {
            reg.counter(&format!(
                "flowmatch_pool_{field}_total{{pool=\"{label}\"}}"
            ))
        };
        Self {
            label: label.to_string(),
            served: c("served"),
            rejected: c("rejected"),
            failed: c("failed"),
            retries: c("retries"),
            breaker_skips: c("breaker_skips"),
            deadline_misses: c("deadline_misses"),
            warm_served: c("warm_served"),
            sessions_evicted: c("sessions_evicted"),
            batches: c("batches"),
            batched_jobs: c("batched_jobs"),
            padding_waste_cells: c("padding_waste_cells"),
            linger_sheds: c("linger_sheds"),
            latency: reg.histogram(
                &format!("flowmatch_pool_latency_seconds{{pool=\"{label}\"}}"),
                obs::LATENCY_BUCKETS,
            ),
        }
    }
}

struct PoolMetrics {
    overall: LatencyRecorder,
    assign: LatencyRecorder,
    grid: LatencyRecorder,
    per_class: [LatencyRecorder; 3],
    rejected: usize,
    failed: usize,
    retries: u64,
    breaker_skips: u64,
    deadline_misses: usize,
    warm_served: usize,
    sessions_evicted: usize,
    batches: usize,
    batched_jobs: usize,
    padding_waste_cells: u64,
    linger_sheds: usize,
    backends: BTreeMap<&'static str, usize>,
    twins: MetricTwins,
}

impl PoolMetrics {
    fn new(label: &str) -> Self {
        Self {
            overall: LatencyRecorder::new(),
            assign: LatencyRecorder::new(),
            grid: LatencyRecorder::new(),
            per_class: [
                LatencyRecorder::new(),
                LatencyRecorder::new(),
                LatencyRecorder::new(),
            ],
            rejected: 0,
            failed: 0,
            retries: 0,
            breaker_skips: 0,
            deadline_misses: 0,
            warm_served: 0,
            sessions_evicted: 0,
            batches: 0,
            batched_jobs: 0,
            padding_waste_cells: 0,
            linger_sheds: 0,
            backends: BTreeMap::new(),
            twins: MetricTwins::new(label),
        }
    }

    fn record(&mut self, class: SizeClass, family: &'static str, backend: &'static str, lat: f64) {
        self.overall.record(lat);
        if family == "assignment" {
            self.assign.record(lat);
        } else {
            self.grid.record(lat);
        }
        self.per_class[class.index()].record(lat);
        *self.backends.entry(backend).or_insert(0) += 1;
        self.twins.served.inc();
        self.twins.latency.observe(lat);
        // Per-family / per-class / per-backend served counts get their
        // own families (not extra labels on `_served_total`) so prefix
        // sums over one family never double count.
        let reg = obs::global();
        let pool = &self.twins.label;
        reg.counter(&format!(
            "flowmatch_pool_family_served_total{{pool=\"{pool}\",family=\"{family}\"}}"
        ))
        .inc();
        reg.counter(&format!(
            "flowmatch_pool_class_served_total{{pool=\"{pool}\",class=\"{}\"}}",
            class.name()
        ))
        .inc();
        reg.counter(&format!(
            "flowmatch_pool_backend_served_total{{pool=\"{pool}\",backend=\"{backend}\"}}"
        ))
        .inc();
    }

    fn reject(&mut self, n: usize) {
        self.rejected += n;
        self.twins.rejected.add(n as u64);
    }

    fn deadline_miss(&mut self, n: usize) {
        self.deadline_misses += n;
        self.twins.deadline_misses.add(n as u64);
    }

    fn fail(&mut self) {
        self.failed += 1;
        self.twins.failed.inc();
    }

    fn add_retries(&mut self, n: u64) {
        self.retries += n;
        self.twins.retries.add(n);
    }

    fn add_breaker_skips(&mut self, n: u64) {
        self.breaker_skips += n;
        self.twins.breaker_skips.add(n);
    }

    fn warm(&mut self) {
        self.warm_served += 1;
        self.twins.warm_served.inc();
    }

    fn evict_sessions(&mut self, n: usize) {
        self.sessions_evicted += n;
        self.twins.sessions_evicted.add(n as u64);
    }

    /// One joint device dispatch served `jobs` requests, wasting
    /// `waste_cells` padded slab cells over their logical sizes.
    fn batch_dispatched(&mut self, jobs: usize, waste_cells: u64) {
        self.batches += 1;
        self.batched_jobs += jobs;
        self.padding_waste_cells += waste_cells;
        self.twins.batches.inc();
        self.twins.batched_jobs.add(jobs as u64);
        self.twins.padding_waste_cells.add(waste_cells);
    }

    /// Jobs cut into a batch whose deadline died during the linger —
    /// answered `DeadlineExceeded` instead of padded into the dispatch.
    fn linger_shed(&mut self, n: usize) {
        self.linger_sheds += n;
        self.twins.linger_sheds.add(n as u64);
    }
}

/// Aggregate pool statistics, collected at shutdown.
#[derive(Debug, Clone)]
pub struct PoolReport {
    pub served: usize,
    pub rejected: usize,
    pub assign_served: usize,
    pub grid_served: usize,
    /// End-to-end latency (submit → reply) over all served requests.
    pub latency: Option<Summary>,
    pub assign_latency: Option<Summary>,
    pub grid_latency: Option<Summary>,
    /// Latency per size class, indexed by [`SizeClass::index`].
    pub class_latency: [Option<Summary>; 3],
    pub throughput_rps: f64,
    /// Requests served per backend name.
    pub backends: Vec<(&'static str, usize)>,
    /// Routing telemetry: per-(family × class × backend) route counts
    /// and latency EWMAs, in stable order.  Populated in both modes —
    /// static deployments get the same per-backend observability.
    pub routes: Vec<RouteStat>,
    /// Large grid solves the adaptive router spilled to
    /// `fifo-lockfree` because the wave pool was saturated.
    pub spilled: usize,
    /// Requests that exhausted their retry budget (replied `Failed`).
    pub failed: usize,
    /// Retry attempts across all requests (successful or not).
    pub retries: u64,
    /// Candidate backends skipped because their circuit breaker was open.
    pub breaker_skips: u64,
    /// Requests shed before dispatch or cancelled mid-solve because
    /// their deadline passed.
    pub deadline_misses: usize,
    /// Session updates served warm (incremental delta solves on a
    /// retained residual cache).
    pub warm_served: usize,
    /// Warm-start sessions evicted by the per-worker LRU byte budget.
    pub sessions_evicted: usize,
    /// Joint device dispatches served by the batched grid backend
    /// (each one cut ≥ 2 compatible jobs from a shard queue).
    pub batches: usize,
    /// Requests served inside those joint dispatches.
    pub batched_jobs: usize,
    /// Padded slab cells the joint dispatches shipped beyond the live
    /// instances' logical sizes (the padding tax of micro-batching).
    pub padding_waste_cells: u64,
    /// Jobs cut into a batch whose deadline died during the linger,
    /// answered `DeadlineExceeded` instead of padded into the dispatch.
    pub linger_sheds: usize,
    /// Circuit-breaker states per (family × class × backend) at
    /// shutdown, in stable order.
    pub breakers: Vec<BreakerStat>,
    /// Wave-pool worker threads respawned after a hostile panic.
    pub respawns: u64,
}

impl PoolReport {
    pub fn served_by(&self, backend: &str) -> usize {
        self.backends
            .iter()
            .find(|(b, _)| *b == backend)
            .map_or(0, |(_, n)| *n)
    }

    /// Breakers currently open (half-open ones already admit traffic).
    pub fn breakers_open(&self) -> usize {
        self.breakers.iter().filter(|b| b.is_open()).count()
    }
}

/// The sharded solver-pool service: one runtime serving both paper
/// algorithms (grid max-flow and assignment) behind a single
/// submit/reply API, with persistent workers, size-class sharding,
/// admission control, and per-worker backend caches.
pub struct SolverPool {
    queues: Arc<ShardedQueues>,
    metrics: Arc<Mutex<PoolMetrics>>,
    telemetry: Arc<TelemetrySink>,
    wave_pool: Arc<WorkerPool>,
    directory: Arc<SessionDirectory>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    /// This pool's registry label value (`p0`, `p1`, …); every
    /// `flowmatch_pool_*` series this pool writes carries
    /// `pool="<label>"`.
    label: String,
}

impl SolverPool {
    /// Start the pool: spawn `cfg.workers` long-lived solver workers
    /// (0 is allowed and useful in tests: admission-only, nothing
    /// drains) plus one shared wave [`WorkerPool`] that the grid
    /// `native-par` backend borrows for its tile phases.
    pub fn start(cfg: PoolConfig) -> Self {
        // Pin the log level before the first worker spawns, so every
        // worker thread observes the same `FLOWMATCH_LOG` decision.
        crate::util::logging::ensure_init();
        let label = format!("p{}", POOL_SEQ.fetch_add(1, Ordering::Relaxed));
        let queues = Arc::new(ShardedQueues::new(cfg.shard.clone(), cfg.workers));
        let metrics = Arc::new(Mutex::new(PoolMetrics::new(&label)));
        // One telemetry sink shared by every worker: route decisions,
        // EWMAs, and circuit-breaker state are pool-global, not
        // per-worker.
        let telemetry = Arc::new(TelemetrySink::with_breaker(
            cfg.router.probe_every,
            cfg.router.breaker_threshold,
            cfg.router.breaker_cooldown,
        ));
        let wave_pool = Arc::new(WorkerPool::new(cfg.router.par_threads));
        let directory = Arc::new(SessionDirectory::default());
        let session_budget = cfg.session_budget_mb.saturating_mul(1 << 20);
        let workers = (0..cfg.workers)
            .map(|idx| {
                let queues = Arc::clone(&queues);
                let metrics = Arc::clone(&metrics);
                let telemetry = Arc::clone(&telemetry);
                let wave_pool = Arc::clone(&wave_pool);
                let directory = Arc::clone(&directory);
                let rcfg = cfg.router.clone();
                let total = cfg.workers;
                let label = label.clone();
                std::thread::Builder::new()
                    .name(format!("flowmatch-solver-{idx}"))
                    .spawn(move || {
                        solver_worker_loop(
                            idx,
                            total,
                            queues,
                            metrics,
                            telemetry,
                            rcfg,
                            wave_pool,
                            directory,
                            session_budget,
                            label,
                        )
                    })
                    .expect("spawn solver worker")
            })
            .collect();
        Self {
            queues,
            metrics,
            telemetry,
            wave_pool,
            directory,
            workers,
            next_id: AtomicU64::new(0),
            label,
        }
    }

    /// The `pool="..."` label value this pool's registry series carry.
    pub fn metrics_label(&self) -> &str {
        &self.label
    }

    /// Publish the point-in-time introspection gauges: per-class shard
    /// depth, pinned-lane backlog, open breakers, and live warm-start
    /// sessions.  The serve loop calls this on every metrics interval
    /// (and once at shutdown); it reads queue locks only, never blocks
    /// a solve.
    pub fn publish_gauges(&self) {
        let reg = obs::global();
        let label = &self.label;
        for class in SizeClass::ALL {
            reg.gauge(&format!(
                "flowmatch_shard_depth{{pool=\"{label}\",class=\"{}\"}}",
                class.name()
            ))
            .set(self.queues.depth(class) as i64);
        }
        reg.gauge(&format!("flowmatch_pinned_depth{{pool=\"{label}\"}}"))
            .set(self.queues.pinned_depth() as i64);
        reg.gauge(&format!("flowmatch_breakers_open{{pool=\"{label}\"}}"))
            .set(self.telemetry.breakers_open() as i64);
        reg.gauge(&format!("flowmatch_sessions_live{{pool=\"{label}\"}}"))
            .set(self.directory.len() as i64);
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// The shared wave pool (exposed so callers can run pooled grid
    /// executors outside the service path).
    pub fn wave_pool(&self) -> &Arc<WorkerPool> {
        &self.wave_pool
    }

    /// Submit with synchronous admission control: `Err` is the
    /// backpressure signal (queue full / too large / shutting down).
    pub fn try_submit(
        &self,
        instance: ProblemInstance,
    ) -> Result<mpsc::Receiver<Result<SolveReply, ReplyError>>, RejectReason> {
        self.try_submit_with_deadline(instance, None)
    }

    /// [`SolverPool::try_submit`] with an optional per-request deadline
    /// budget.  A request whose deadline passes while it is still
    /// queued is shed at dispatch (`RejectReason::DeadlineExceeded`)
    /// instead of occupying a worker; one that is already solving is
    /// cancelled cooperatively at the next host-round boundary.
    pub fn try_submit_with_deadline(
        &self,
        instance: ProblemInstance,
        timeout: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<SolveReply, ReplyError>>, RejectReason> {
        self.submit_solve(instance, timeout, false)
    }

    /// Submit a grid instance *and open a warm-start session* for it:
    /// the worker keeps the solved residual state, and the reply's
    /// `session` field carries the id to address updates to.  On a
    /// non-grid instance the request degrades to a plain cold solve
    /// (assignment solves have no residual state worth keeping).
    pub fn try_submit_session(
        &self,
        instance: ProblemInstance,
        timeout: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<SolveReply, ReplyError>>, RejectReason> {
        let open = matches!(instance, ProblemInstance::Grid(_));
        self.submit_solve(instance, timeout, open)
    }

    /// Submit a delta update against an open session.  Routed sticky to
    /// the worker holding the session's residual cache; if the session
    /// is unknown (never opened, LRU-evicted, or dropped after a failed
    /// update) the receiver yields [`ReplyError::SessionEvicted`] and
    /// the caller falls back to a cold solve of its edited graph.
    pub fn try_submit_update(
        &self,
        session_id: u64,
        deltas: Vec<CapacityDelta>,
        timeout: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<SolveReply, ReplyError>>, RejectReason> {
        let Some((worker, class)) = self.directory.lookup(session_id) else {
            let (tx, rx) = mpsc::channel();
            let _ = tx.send(Err(ReplyError::SessionEvicted));
            return Ok(rx);
        };
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let job = QueuedJob {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            class,
            payload: JobPayload::Update { session_id, deltas },
            submitted: now,
            deadline: timeout.map(|t| now + t),
            reply: tx,
        };
        let mut shed = Vec::new();
        let pushed = self.queues.push_pinned(job, worker, &mut shed);
        shed_expired(&self.metrics, &mut shed);
        match pushed {
            Ok(()) => Ok(rx),
            Err((job, reason)) => {
                drop(job);
                self.metrics.lock().unwrap().reject(1);
                Err(reason)
            }
        }
    }

    fn submit_solve(
        &self,
        instance: ProblemInstance,
        timeout: Option<Duration>,
        open_session: bool,
    ) -> Result<mpsc::Receiver<Result<SolveReply, ReplyError>>, RejectReason> {
        let cfg = self.queues.config();
        let units = instance.work_units();
        if units > cfg.max_units {
            let reason = RejectReason::TooLarge {
                units,
                max_units: cfg.max_units,
            };
            self.metrics.lock().unwrap().reject(1);
            return Err(reason);
        }
        let class = cfg.classify(units);
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let job = QueuedJob {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            class,
            payload: JobPayload::Solve {
                instance,
                open_session,
            },
            submitted: now,
            deadline: timeout.map(|t| now + t),
            reply: tx,
        };
        let mut shed = Vec::new();
        let pushed = self.queues.push(job, &mut shed);
        shed_expired(&self.metrics, &mut shed);
        match pushed {
            Ok(()) => Ok(rx),
            Err((job, reason)) => {
                drop(job);
                self.metrics.lock().unwrap().reject(1);
                Err(reason)
            }
        }
    }

    /// Submit returning a receiver unconditionally: a rejection arrives
    /// through the channel as `Err(ReplyError::Rejected(..))` (the
    /// legacy `AssignmentService` shape).
    pub fn submit(
        &self,
        instance: ProblemInstance,
    ) -> mpsc::Receiver<Result<SolveReply, ReplyError>> {
        match self.try_submit(instance) {
            Ok(rx) => rx,
            Err(reason) => {
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Err(ReplyError::Rejected(reason)));
                rx
            }
        }
    }

    /// Drain the queues, stop the workers, and report.
    pub fn shutdown(mut self) -> PoolReport {
        self.finish();
        // Final gauge states (depths drained to zero, surviving
        // sessions) so a post-shutdown exposition dump is coherent.
        self.publish_gauges();
        let routes = self.telemetry.snapshot();
        let spilled = self.telemetry.spills();
        let breakers = self.telemetry.breaker_snapshot();
        let respawns = self.wave_pool.respawns();
        let m = self.metrics.lock().unwrap();
        PoolReport {
            routes,
            spilled,
            breakers,
            respawns,
            failed: m.failed,
            retries: m.retries,
            breaker_skips: m.breaker_skips,
            deadline_misses: m.deadline_misses,
            warm_served: m.warm_served,
            sessions_evicted: m.sessions_evicted,
            batches: m.batches,
            batched_jobs: m.batched_jobs,
            padding_waste_cells: m.padding_waste_cells,
            linger_sheds: m.linger_sheds,
            served: m.overall.count(),
            rejected: m.rejected,
            assign_served: m.assign.count(),
            grid_served: m.grid.count(),
            latency: m.overall.summary(),
            assign_latency: m.assign.summary(),
            grid_latency: m.grid.summary(),
            class_latency: [
                m.per_class[0].summary(),
                m.per_class[1].summary(),
                m.per_class[2].summary(),
            ],
            throughput_rps: m.overall.throughput(),
            backends: m.backends.iter().map(|(k, v)| (*k, *v)).collect(),
        }
    }

    fn finish(&mut self) {
        self.queues.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for SolverPool {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Reply `DeadlineExceeded` to every job the queue scans shed, and
/// count the misses.  Shared by the submit paths (full-shard sweep)
/// and the worker loop (pop-scan sweep).
fn shed_expired(metrics: &Mutex<PoolMetrics>, shed: &mut Vec<QueuedJob>) {
    if shed.is_empty() {
        return;
    }
    {
        let mut m = metrics.lock().unwrap();
        m.reject(shed.len());
        m.deadline_miss(shed.len());
    }
    for job in shed.drain(..) {
        let _ = job
            .reply
            .send(Err(ReplyError::Rejected(RejectReason::DeadlineExceeded)));
    }
}

/// The reply's phase breakdown: the engine's own phase timings for
/// grid solves (assignment engines report flat counters, not phases)
/// plus the time this request spent queued.  Also flushes the queue
/// wait into the registry under `family="service"` so queue pressure
/// shows up in the exposition without a reply in hand.
fn reply_phases(queue_delay: f64, outcome: &super::SolveOutcome) -> Option<PhaseBreakdown> {
    let mut p = match outcome {
        super::SolveOutcome::Grid(report) => report.phases,
        _ => PhaseBreakdown::default(),
    };
    p.add(Phase::QueueWait, queue_delay);
    obs::record_phase_secs("service", Phase::QueueWait, queue_delay);
    Some(p)
}

/// Padded-slab cells a joint dispatch wastes beyond the live
/// instances' logical sizes — K · Hmax · Wmax − Σ h·w, mirroring the
/// batched driver's own accounting from instance dims alone.
fn batch_padding_cells(instances: &[ProblemInstance]) -> u64 {
    let (mut hmax, mut wmax, mut logical) = (0u64, 0u64, 0u64);
    for inst in instances {
        if let ProblemInstance::Grid(net) = inst {
            hmax = hmax.max(net.height as u64);
            wmax = wmax.max(net.width as u64);
            logical += (net.height * net.width) as u64;
        }
    }
    (instances.len() as u64 * hmax * wmax).saturating_sub(logical)
}

/// Joint device dispatch for a batch cut from the shard queues.
/// Replies in place to every slot the batched backend served or
/// cancelled — each under its **own** deadline and latency clock — and
/// returns the jobs that still need the ordinary per-job path: the
/// whole batch when the router or backend declined it, the failed
/// slots otherwise (each re-solved on the full retry/fallback chain).
fn dispatch_batch(
    worker: usize,
    backends: &mut WorkerBackends,
    metrics: &Mutex<PoolMetrics>,
    batch: Vec<QueuedJob>,
) -> Vec<QueuedJob> {
    // Second-chance shed: a job whose deadline died during the linger
    // is answered now, never padded into the dispatch (a batch inherits
    // nobody's budget — not its slackest member's, not its deadest's).
    let mut live = Vec::with_capacity(batch.len());
    for job in batch {
        if job.deadline.map_or(false, |dl| Instant::now() >= dl) {
            {
                let mut m = metrics.lock().unwrap();
                m.reject(1);
                m.deadline_miss(1);
                m.linger_shed(1);
            }
            let _ = job
                .reply
                .send(Err(ReplyError::Rejected(RejectReason::DeadlineExceeded)));
        } else {
            live.push(job);
        }
    }
    if live.len() < 2 {
        return live;
    }
    let class = live[0].class;
    // Pull the instances out of the payloads; per-job metadata
    // (deadline, reply channel) rides alongside so replies fan back
    // per job.
    let mut metas = Vec::with_capacity(live.len());
    let mut instances = Vec::with_capacity(live.len());
    for job in live {
        let QueuedJob {
            id,
            class,
            payload,
            submitted,
            deadline,
            reply,
        } = job;
        let JobPayload::Solve { instance, .. } = payload else {
            unreachable!("pop_batch cuts plain solve jobs only");
        };
        metas.push((id, class, submitted, deadline, reply));
        instances.push(instance);
    }
    let cancels: Vec<CancelToken> = metas
        .iter()
        .map(|m| CancelToken::with_deadline(m.3))
        .collect();
    let dispatched = Instant::now();
    let Some(results) = backends.solve_batch(class, &instances, &cancels) else {
        // Declined (backend gated off, breaker open, or adaptive
        // routing prefers another engine): rebuild the jobs untouched.
        return metas
            .into_iter()
            .zip(instances)
            .map(|((id, class, submitted, deadline, reply), instance)| QueuedJob {
                id,
                class,
                payload: JobPayload::Solve {
                    instance,
                    open_session: false,
                },
                submitted,
                deadline,
                reply,
            })
            .collect();
    };
    metrics
        .lock()
        .unwrap()
        .batch_dispatched(instances.len(), batch_padding_cells(&instances));
    let mut fallback = Vec::new();
    for ((meta, instance), slot) in metas.into_iter().zip(instances).zip(results) {
        let (id, class, submitted, deadline, reply) = meta;
        let queue_delay = dispatched.saturating_duration_since(submitted).as_secs_f64();
        match slot {
            Ok(served) => {
                let latency = submitted.elapsed().as_secs_f64();
                let mut m = metrics.lock().unwrap();
                m.record(class, served.outcome.family(), served.backend, latency);
                drop(m);
                let _ = reply.send(Ok(SolveReply {
                    id,
                    class,
                    worker,
                    backend: served.backend,
                    latency,
                    queue_delay,
                    retries: served.retries,
                    breaker_skips: served.breaker_skips,
                    session: None,
                    warm: false,
                    phases: reply_phases(queue_delay, &served.outcome),
                    outcome: served.outcome,
                }));
            }
            Err(fail) if fail.cancelled => {
                let mut m = metrics.lock().unwrap();
                m.fail();
                m.deadline_miss(1);
                drop(m);
                let _ = reply.send(Err(ReplyError::Failed {
                    message: fail.error,
                    retries: fail.retries,
                }));
            }
            Err(_) => {
                // Its telemetry strike is already recorded; the request
                // itself re-solves per instance on the retry/fallback
                // chain.
                fallback.push(QueuedJob {
                    id,
                    class,
                    payload: JobPayload::Solve {
                        instance,
                        open_session: false,
                    },
                    submitted,
                    deadline,
                    reply,
                });
            }
        }
    }
    fallback
}

#[allow(clippy::too_many_arguments)]
fn solver_worker_loop(
    idx: usize,
    total: usize,
    queues: Arc<ShardedQueues>,
    metrics: Arc<Mutex<PoolMetrics>>,
    telemetry: Arc<TelemetrySink>,
    rcfg: RouterConfig,
    wave_pool: Arc<WorkerPool>,
    directory: Arc<SessionDirectory>,
    session_budget: usize,
    label: String,
) {
    // Per-worker backend state: cached executors/scratch and (when
    // configured and discoverable) a PJRT driver.  The `xla` handles
    // are !Send, exactly like a CUDA context — they live and die on
    // this thread.  The telemetry sink is the one shared measurement
    // store behind adaptive routing.
    let batch_max = rcfg.batch_max.max(1);
    let batch_linger = Duration::from_micros(rcfg.batch_linger_us);
    let mut backends = WorkerBackends::with_telemetry(rcfg, Some(&wave_pool), telemetry);
    // Warm-start sessions live with the worker that opened them (the
    // directory routes updates here); the LRU byte budget bounds their
    // resident residual caches.
    let mut sessions = SessionStore::new(session_budget);
    // Session stores are per-worker (the residual caches are !Send in
    // spirit: engine-shaped, owned here), so the occupancy gauges are
    // set by this thread — nobody else can read the store.
    let store_entries = obs::global().gauge(&format!(
        "flowmatch_session_store_entries{{pool=\"{label}\",worker=\"{idx}\"}}"
    ));
    let store_bytes = obs::global().gauge(&format!(
        "flowmatch_session_store_bytes{{pool=\"{label}\",worker=\"{idx}\"}}"
    ));
    let mut shed = Vec::new();
    // Jobs a cut batch handed back for per-job dispatch (declined
    // batches, failed slots) — served before pulling new work.
    let mut pending: VecDeque<QueuedJob> = VecDeque::new();
    loop {
        let job = if let Some(job) = pending.pop_front() {
            job
        } else {
            let popped = if batch_max > 1 {
                queues.pop_batch(idx, total, batch_max, batch_linger, &mut shed)
            } else {
                queues.pop(idx, total, &mut shed).map(|job| vec![job])
            };
            // Jobs whose deadline passed while queued are answered
            // without ever touching a backend — including when the scan
            // found no live job at all (the pops hand them back instead
            // of blocking).
            let had_shed = !shed.is_empty();
            shed_expired(&metrics, &mut shed);
            let Some(mut batch) = popped else {
                if had_shed {
                    continue; // swept expired jobs; scan again
                }
                break; // shutdown and drained
            };
            if batch.len() > 1 {
                // Joint device dispatch; whatever it hands back (the
                // whole batch if declined, failed slots otherwise)
                // drains through the ordinary per-job path.
                pending = dispatch_batch(idx, &mut backends, &metrics, batch).into();
                continue;
            }
            match batch.pop() {
                Some(job) => job,
                None => continue,
            }
        };
        let queue_delay = job.submitted.elapsed().as_secs_f64();
        // Second-chance deadline shed for the job we are about to run.
        if let Some(dl) = job.deadline {
            if Instant::now() >= dl {
                let mut m = metrics.lock().unwrap();
                m.reject(1);
                m.deadline_miss(1);
                drop(m);
                let _ = job
                    .reply
                    .send(Err(ReplyError::Rejected(RejectReason::DeadlineExceeded)));
                continue;
            }
        }
        let cancel = CancelToken::with_deadline(job.deadline);
        match job.payload {
            JobPayload::Solve {
                ref instance,
                open_session: true,
            } if matches!(instance, ProblemInstance::Grid(_)) => {
                let ProblemInstance::Grid(net) = instance else {
                    unreachable!("guarded by the match arm");
                };
                // Session opens bypass the retry/fallback machinery:
                // the residual cache is engine-shaped, so the solve
                // must run on the engine that will serve the updates.
                let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    backends.solve_session_open(job.class, net, &cancel)
                }));
                let latency = job.submitted.elapsed().as_secs_f64();
                let reply = match solved {
                    Ok(Ok((outcome, state, backend))) => {
                        let evicted = sessions.insert(job.id, state);
                        if !evicted.is_empty() {
                            crate::log_debug!(
                                "worker {idx}: LRU evicted {} session(s) for session {}",
                                evicted.len(),
                                job.id
                            );
                        }
                        for ev in &evicted {
                            directory.remove(*ev);
                        }
                        directory.insert(job.id, idx, job.class);
                        let mut m = metrics.lock().unwrap();
                        m.evict_sessions(evicted.len());
                        m.record(job.class, outcome.family(), backend, latency);
                        drop(m);
                        Ok(SolveReply {
                            id: job.id,
                            class: job.class,
                            worker: idx,
                            backend,
                            latency,
                            queue_delay,
                            retries: 0,
                            breaker_skips: 0,
                            session: Some(job.id),
                            warm: false,
                            phases: reply_phases(queue_delay, &outcome),
                            outcome,
                        })
                    }
                    Ok(Err(err)) => {
                        let cancelled = Cancelled::caused(&err);
                        let mut m = metrics.lock().unwrap();
                        m.fail();
                        if cancelled {
                            m.deadline_miss(1);
                        }
                        drop(m);
                        Err(ReplyError::Failed {
                            message: format!("{err:#}"),
                            retries: 0,
                        })
                    }
                    Err(_) => {
                        crate::log_warn!("worker {idx}: solver panicked opening session {}", job.id);
                        metrics.lock().unwrap().fail();
                        Err(ReplyError::Failed {
                            message: "solver panicked".to_string(),
                            retries: 0,
                        })
                    }
                };
                let _ = job.reply.send(reply);
            }
            JobPayload::Solve { ref instance, .. } => {
                // `WorkerBackends::solve` catches per-attempt panics
                // itself; this outer catch is the last-resort guard
                // keeping the request worker alive if the retry
                // machinery itself blows up.
                let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    backends.solve(job.class, instance, &cancel)
                }));
                let latency = job.submitted.elapsed().as_secs_f64();
                let reply = match solved {
                    Ok(Ok(served)) => {
                        let mut m = metrics.lock().unwrap();
                        m.record(job.class, served.outcome.family(), served.backend, latency);
                        m.add_retries(u64::from(served.retries));
                        m.add_breaker_skips(u64::from(served.breaker_skips));
                        drop(m);
                        Ok(SolveReply {
                            id: job.id,
                            class: job.class,
                            worker: idx,
                            backend: served.backend,
                            latency,
                            queue_delay,
                            retries: served.retries,
                            breaker_skips: served.breaker_skips,
                            session: None,
                            warm: false,
                            phases: reply_phases(queue_delay, &served.outcome),
                            outcome: served.outcome,
                        })
                    }
                    Ok(Err(fail)) => {
                        let mut m = metrics.lock().unwrap();
                        m.fail();
                        m.add_retries(u64::from(fail.retries));
                        if fail.cancelled {
                            m.deadline_miss(1);
                        }
                        drop(m);
                        Err(ReplyError::Failed {
                            message: fail.error,
                            retries: fail.retries,
                        })
                    }
                    Err(_) => {
                        crate::log_warn!("worker {idx}: retry machinery panicked on request {}", job.id);
                        metrics.lock().unwrap().fail();
                        Err(ReplyError::Failed {
                            message: "solver panicked".to_string(),
                            retries: 0,
                        })
                    }
                };
                let _ = job.reply.send(reply);
            }
            JobPayload::Update {
                session_id,
                ref deltas,
            } => {
                let Some(state) = sessions.get_mut(session_id) else {
                    // Evicted (or never here): the client resubmits its
                    // edited graph cold.
                    directory.remove(session_id);
                    let _ = job.reply.send(Err(ReplyError::SessionEvicted));
                    continue;
                };
                let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    backends.solve_session_update(job.class, state, deltas, &cancel)
                }));
                let latency = job.submitted.elapsed().as_secs_f64();
                let reply = match solved {
                    Ok(Ok((outcome, backend))) => {
                        let mut m = metrics.lock().unwrap();
                        m.warm();
                        m.record(job.class, outcome.family(), backend, latency);
                        drop(m);
                        Ok(SolveReply {
                            id: job.id,
                            class: job.class,
                            worker: idx,
                            backend,
                            latency,
                            queue_delay,
                            retries: 0,
                            breaker_skips: 0,
                            session: Some(session_id),
                            warm: true,
                            phases: reply_phases(queue_delay, &outcome),
                            outcome,
                        })
                    }
                    Ok(Err(err)) => {
                        // The repair may have half-applied the deltas:
                        // the cache is no longer trustworthy, drop it.
                        crate::log_debug!(
                            "worker {idx}: dropping session {session_id} after failed update"
                        );
                        sessions.remove(session_id);
                        directory.remove(session_id);
                        let cancelled = Cancelled::caused(&err);
                        let mut m = metrics.lock().unwrap();
                        m.fail();
                        if cancelled {
                            m.deadline_miss(1);
                        }
                        drop(m);
                        Err(ReplyError::Failed {
                            message: format!("{err:#}"),
                            retries: 0,
                        })
                    }
                    Err(_) => {
                        crate::log_warn!(
                            "worker {idx}: solver panicked updating session {session_id}; dropping it"
                        );
                        sessions.remove(session_id);
                        directory.remove(session_id);
                        metrics.lock().unwrap().fail();
                        Err(ReplyError::Failed {
                            message: "solver panicked".to_string(),
                            retries: 0,
                        })
                    }
                };
                let _ = job.reply.send(reply);
            }
        }
        store_entries.set(sessions.len() as i64);
        store_bytes.set(sessions.bytes() as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_run_executes_borrowing_jobs() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in data.chunks_mut(16).enumerate() {
                jobs.push(Box::new(move || {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        *x = (i * 16 + j) as u64;
                    }
                }));
            }
            pool.scope_run(jobs);
        }
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u64));
    }

    #[test]
    fn scope_run_reusable_and_more_jobs_than_threads() {
        let pool = WorkerPool::new(2);
        for round in 0..3 {
            let counter = std::sync::atomic::AtomicUsize::new(0);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for _ in 0..9 {
                jobs.push(Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }));
            }
            pool.scope_run(jobs);
            assert_eq!(counter.load(Ordering::Relaxed), 9, "round {round}");
        }
    }

    #[test]
    fn concurrent_scopes_from_two_threads() {
        let pool = Arc::new(WorkerPool::new(4));
        std::thread::scope(|s| {
            for _ in 0..2 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    for _ in 0..5 {
                        let sum = Mutex::new(0u64);
                        let sum_ref = &sum;
                        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
                        for k in 0..8u64 {
                            jobs.push(Box::new(move || {
                                *sum_ref.lock().unwrap() += k + 1;
                            }));
                        }
                        pool.scope_run(jobs);
                        assert_eq!(*sum.lock().unwrap(), 36);
                    }
                });
            }
        });
    }

    #[test]
    fn pool_job_panic_propagates() {
        let pool = WorkerPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                vec![Box::new(|| {}), Box::new(|| panic!("boom"))];
            pool.scope_run(jobs);
        }));
        assert!(res.is_err());
        // The pool survives a panicked batch.
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {})];
        pool.scope_run(jobs);
    }

    #[test]
    fn try_run_batch_counts_panics_without_panicking_caller() {
        let pool = WorkerPool::new(2);
        let done = std::sync::atomic::AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| {
                done.fetch_add(1, Ordering::Relaxed);
            }),
            Box::new(|| panic!("boom")),
            Box::new(|| panic!("boom again")),
        ];
        assert_eq!(pool.try_run_batch(jobs), 2);
        assert_eq!(done.load(Ordering::Relaxed), 1);
        assert_eq!(pool.respawns(), 0, "ordinary panics are caught per-job");
    }

    /// A panic payload whose own `Drop` panics escapes the per-job
    /// `catch_unwind` (the second panic starts while the caught payload
    /// is being discarded) and kills the worker thread.
    struct HostilePayload;

    impl Drop for HostilePayload {
        fn drop(&mut self) {
            panic!("payload drop bomb");
        }
    }

    #[test]
    fn worker_killed_by_hostile_payload_is_respawned() {
        let pool = WorkerPool::new(1);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| std::panic::panic_any(HostilePayload))];
        assert_eq!(pool.try_run_batch(jobs), 1);
        // The sole worker thread died dropping the payload.  The
        // respawn guard replaces it, so the next batch still runs —
        // this blocks forever if no replacement thread comes up.
        let done = std::sync::atomic::AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            done.fetch_add(1, Ordering::Relaxed);
        })];
        assert_eq!(pool.try_run_batch(jobs), 0);
        assert_eq!(done.load(Ordering::Relaxed), 1);
        assert_eq!(pool.respawns(), 1);
    }
}
