//! Wall-clock timing helpers.

use std::time::Instant;

/// A started stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    /// Seconds since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let t = self.elapsed();
        self.start = Instant::now();
        t
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotone() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn time_returns_result() {
        let (v, secs) = time(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let lap = t.lap();
        assert!(lap >= 0.002);
        assert!(t.elapsed() < lap + 0.002);
    }
}
