//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Workload generation must be reproducible across runs and platforms (the
//! benches fix seeds per experiment row), so we use a well-known generator
//! with published reference outputs rather than `rand`'s opaque defaults.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the full 256-bit state from a single u64 via SplitMix64, as the
    /// xoshiro authors recommend.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for workload generation; bound must be non-zero).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "empty range");
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independently-seeded child generator (for per-thread
    /// workloads derived from one experiment seed).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_stays_in_bounds_and_hits_all_residues() {
        let mut rng = Rng::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_endpoints_reachable() {
        let mut rng = Rng::seeded(4);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            match rng.range_i64(-2, 2) {
                -2 => lo_seen = true,
                2 => hi_seen = true,
                v => assert!((-2..=2).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::seeded(5);
        for _ in 0..1000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::seeded(9);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
