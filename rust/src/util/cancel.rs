//! Cooperative cancellation: a cloneable token long-running solves poll
//! at their natural pause points (host-round boundaries, global-relabel
//! entry points).
//!
//! A token is cancelled either explicitly ([`CancelToken::cancel`], any
//! clone observes it) or implicitly by an attached deadline.  Engines
//! call [`CancelToken::check`] and propagate the typed [`Cancelled`]
//! error through their ordinary `Result` plumbing; the service detects
//! it by downcast ([`Cancelled::caused`]) and turns it into a
//! deadline-exceeded reply instead of a retryable backend failure.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// The typed cancellation error.  Kept payload-free so it survives any
/// number of `anyhow` context layers and can be recognised by downcast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cancelled;

impl fmt::Display for Cancelled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "solve cancelled (deadline exceeded or caller gave up)")
    }
}

impl std::error::Error for Cancelled {}

impl Cancelled {
    /// Whether `err` is (or wraps) a cancellation.  `anyhow` preserves
    /// downcast through `.context(...)` layers, so engines may annotate
    /// the error freely as long as they propagate it with `?`.
    pub fn caused(err: &anyhow::Error) -> bool {
        err.downcast_ref::<Cancelled>().is_some()
    }
}

/// A cloneable cancel token: all clones share one flag, and an optional
/// deadline cancels the token implicitly once it passes.  There is no
/// timer thread — the deadline is evaluated lazily at each poll, which
/// is exactly the granularity cooperative cancellation can honour.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that only cancels explicitly.
    pub fn new() -> Self {
        Self::default()
    }

    /// A token that also cancels once `deadline` passes (`None` behaves
    /// like [`CancelToken::new`]).
    pub fn with_deadline(deadline: Option<Instant>) -> Self {
        Self {
            flag: Arc::new(AtomicBool::new(false)),
            deadline,
        }
    }

    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Cancel explicitly; every clone observes it.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        if self.flag.load(Ordering::Acquire) {
            return true;
        }
        match self.deadline {
            Some(dl) => Instant::now() >= dl,
            None => false,
        }
    }

    /// Poll point: `Err(Cancelled)` once the token is cancelled.  The
    /// `?` operator converts into `anyhow::Error` at engine call sites.
    pub fn check(&self) -> Result<(), Cancelled> {
        if self.is_cancelled() {
            Err(Cancelled)
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fresh_token_is_live() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert!(t.check().is_ok());
    }

    #[test]
    fn explicit_cancel_is_shared_across_clones() {
        let t = CancelToken::new();
        let clone = t.clone();
        clone.cancel();
        assert!(t.is_cancelled());
        assert_eq!(t.check(), Err(Cancelled));
    }

    #[test]
    fn past_deadline_cancels_implicitly() {
        let t = CancelToken::with_deadline(Some(Instant::now() - Duration::from_millis(1)));
        assert!(t.is_cancelled());
        let future = CancelToken::with_deadline(Some(Instant::now() + Duration::from_secs(3600)));
        assert!(!future.is_cancelled());
    }

    #[test]
    fn cancelled_survives_anyhow_context() {
        use anyhow::Context;
        let t = CancelToken::new();
        t.cancel();
        let err: anyhow::Error = t
            .check()
            .context("inside the hybrid loop")
            .context("request 42")
            .unwrap_err();
        assert!(Cancelled::caused(&err), "{err:#}");
        let other = anyhow::anyhow!("unrelated");
        assert!(!Cancelled::caused(&other));
    }
}
