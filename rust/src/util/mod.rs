//! Small self-contained substrates: PRNG, statistics, timing, logging.
//!
//! The build image vendors only the `xla` crate's dependency closure, so
//! the crates a project would normally pull in (`rand`, `log`, `criterion`
//! internals) are provided here as minimal, well-tested equivalents.

pub mod cancel;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use cancel::{CancelToken, Cancelled};
pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;
