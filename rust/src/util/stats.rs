//! Sample statistics for bench reporting: mean, stddev, percentiles.

/// Summary statistics over a set of f64 samples (times in seconds, op
/// counts, byte counts — anything the benches record).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample set.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = if count > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (count as f64 - 1.0)
        } else {
            0.0
        };
        Some(Summary {
            count,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[count - 1],
        })
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Format seconds in the most readable unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Format a count with thousands separators (for op-count tables).
pub fn fmt_count(n: i64) -> String {
    let raw = n.abs().to_string();
    let mut out = String::new();
    for (i, c) in raw.chars().enumerate() {
        if i > 0 && (raw.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(c);
    }
    if n < 0 {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::of(&[2.0, 2.0, 2.0]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let samples: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&samples).unwrap();
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.max, 99.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[5.0]).unwrap();
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(1.5), "1.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(5e-9), "5 ns");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(1234567), "1_234_567");
        assert_eq!(fmt_count(-42), "-42");
        assert_eq!(fmt_count(0), "0");
    }
}
