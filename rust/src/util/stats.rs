//! Sample statistics shared by every reporting layer: `Summary`
//! (mean/stddev/percentiles), `LatencyRecorder` (the one per-request
//! latency accumulator — the coordinator service and the solver pool
//! both sit on it), and `Ewma` (the exponentially weighted average the
//! adaptive router's telemetry sink keeps per backend).

/// Summary statistics over a set of f64 samples (times in seconds, op
/// counts, byte counts — anything the benches record).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample set.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = if count > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (count as f64 - 1.0)
        } else {
            0.0
        };
        Some(Summary {
            count,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[count - 1],
        })
    }
}

/// Accumulates per-request latencies (seconds) and summarises them.
/// This is the single recorder behind both the legacy coordinator
/// service report and the solver pool's metrics.
///
/// Timing goes through [`crate::util::Timer`] — the one wall-clock
/// helper — instead of keeping a private pair of `Instant`s (the old
/// split between here and `util::timer` is gone).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    /// Started at the first `mark_start`/`record`.
    window: Option<super::Timer>,
    /// Seconds from window start to the most recent `record`.
    window_secs: f64,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&mut self) {
        self.window.get_or_insert_with(super::Timer::start);
    }

    pub fn record(&mut self, latency_secs: f64) {
        self.mark_start();
        self.samples.push(latency_secs);
        self.window_secs = self.window.as_ref().map_or(0.0, |t| t.elapsed());
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn summary(&self) -> Option<Summary> {
        Summary::of(&self.samples)
    }

    /// Requests per second over the recording window.
    pub fn throughput(&self) -> f64 {
        if self.window_secs > 0.0 {
            self.samples.len() as f64 / self.window_secs
        } else {
            0.0
        }
    }
}

/// Exponentially weighted moving average: `v ← (1-α)·v + α·x`.  The
/// adaptive router keeps one per (family × size class × backend); a
/// fixed α trades smoothing for how fast a regressing backend is
/// demoted.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
    count: u64,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "EWMA alpha out of range");
        Self {
            alpha,
            value: None,
            count: 0,
        }
    }

    pub fn record(&mut self, sample: f64) {
        self.value = Some(match self.value {
            None => sample,
            Some(v) => (1.0 - self.alpha) * v + self.alpha * sample,
        });
        self.count += 1;
    }

    /// Current average; `None` until the first sample.
    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Nearest-rank percentile on a pre-sorted slice.  Total on all
/// inputs: an empty slice yields `0.0` (callers that must distinguish
/// "no samples" go through [`Summary::of`], which returns `None`), a
/// single sample is every percentile of itself, and `q` is clamped to
/// `[0, 1]` instead of panicking.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Format seconds in the most readable unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Format `name=count` pairs for one-line breakdowns (reject reasons,
/// per-backend served counts) — one formatter for the CLI and benches.
pub fn fmt_count_pairs(pairs: &[(&str, usize)]) -> String {
    let parts: Vec<String> = pairs.iter().map(|(k, v)| format!("{k}={v}")).collect();
    parts.join(", ")
}

/// Format a count with thousands separators (for op-count tables).
pub fn fmt_count(n: i64) -> String {
    let raw = n.abs().to_string();
    let mut out = String::new();
    for (i, c) in raw.chars().enumerate() {
        if i > 0 && (raw.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(c);
    }
    if n < 0 {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::of(&[2.0, 2.0, 2.0]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let samples: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&samples).unwrap();
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.max, 99.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[5.0]).unwrap();
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p95, 5.0);
        assert_eq!(s.p99, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_is_total() {
        // Empty and out-of-range inputs must not panic.
        assert_eq!(percentile(&[], 0.99), 0.0);
        assert_eq!(percentile(&[3.0], 0.0), 3.0);
        assert_eq!(percentile(&[3.0], 1.0), 3.0);
        assert_eq!(percentile(&[1.0, 2.0], 7.5), 2.0); // clamped to max
        assert_eq!(percentile(&[1.0, 2.0], -1.0), 1.0); // clamped to min
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(1.5), "1.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(5e-9), "5 ns");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(1234567), "1_234_567");
        assert_eq!(fmt_count(-42), "-42");
        assert_eq!(fmt_count(0), "0");
    }

    #[test]
    fn count_pairs_formatting() {
        assert_eq!(fmt_count_pairs(&[]), "");
        assert_eq!(
            fmt_count_pairs(&[("queue-full", 3), ("too-large", 1)]),
            "queue-full=3, too-large=1"
        );
    }

    #[test]
    fn recorder_records_and_summarises() {
        let mut r = LatencyRecorder::new();
        r.record(0.010);
        r.record(0.020);
        r.record(0.030);
        let s = r.summary().unwrap();
        assert_eq!(s.count, 3);
        assert!((s.mean - 0.020).abs() < 1e-9);
        assert!(r.throughput() >= 0.0);
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::new();
        assert!(r.summary().is_none());
        assert_eq!(r.throughput(), 0.0);
    }

    #[test]
    fn ewma_converges_toward_recent_samples() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        e.record(8.0);
        assert_eq!(e.get(), Some(8.0)); // first sample seeds the average
        for _ in 0..20 {
            e.record(1.0);
        }
        let v = e.get().unwrap();
        assert!(v < 1.01, "ewma {v} did not track the recent level");
        assert_eq!(e.count(), 21);
    }
}
