//! Minimal leveled logger (stderr), controlled by `FLOWMATCH_LOG`.
//!
//! Levels: `error` < `warn` < `info` (default) < `debug` < `trace`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static INIT: OnceLock<()> = OnceLock::new();

fn init_from_env() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("FLOWMATCH_LOG") {
            if let Some(l) = Level::parse(&v) {
                MAX_LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

/// Force the `FLOWMATCH_LOG` read to happen *now*.  The level lives in
/// one process-global atomic, so any thread spawned after this call
/// observes the configured level deterministically — thread-spawning
/// layers (the solver pool, the CLI entry point) call this before
/// their first `spawn` instead of racing the lazy init against worker
/// startup.
pub fn ensure_init() {
    init_from_env();
}

/// Override the level programmatically (CLI `--log-level`).
pub fn set_level(level: Level) {
    init_from_env();
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init_from_env();
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{} {}] {}", level.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
