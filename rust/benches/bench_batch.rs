//! E16: batched device execution for the grid family.
//!
//! Two comparisons:
//!
//! * **dispatch level**: K same-class grid instances solved through one
//!   padded batched dispatch (`BatchGridSolver` over a
//!   `BatchedGridDriver`) against K per-instance device solves
//!   (`GridEngine::Pjrt`) and the native oracle, across batch widths
//!   and a ragged mix.  The bit-exact contract is asserted on every
//!   combination before any timing is reported, and the driver's own
//!   dispatch stats contribute padding-waste and transfer-overlap
//!   columns.
//! * **service level**: the same closed-loop grid burst replayed
//!   against a pool with micro-batching off (`batch_max = 1`, the
//!   default) and on (`batch_max = 8`), with the pool's batch counters
//!   alongside throughput.
//!
//! Emits benchkit JSON (default `benches/data/bench_batch.json`,
//! override with `FLOWMATCH_BENCH_JSON`).

use flowmatch::benchkit::{write_json, Cell, Measure, Table};
use flowmatch::coordinator::{solve_grid_with, GridEngine};
use flowmatch::graph::GridNetwork;
use flowmatch::gridflow::{padded_class, BatchGridSolver};
use flowmatch::runtime::BatchedGridDriver;
use flowmatch::service::{replay, PoolConfig, SolverPool};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::{random_grid, MixedTrace, MixedTraceConfig, TraceConfig};

const CYCLE: usize = 128;

fn uniform_nets(seed: u64, k: usize, size: usize) -> Vec<GridNetwork> {
    let mut rng = Rng::seeded(seed);
    (0..k)
        .map(|_| random_grid(&mut rng, size, size, 20, 0.3, 0.3))
        .collect()
}

/// Ragged mix: four shapes padded to one envelope, the worst packing
/// the shard compatibility cut will actually emit.
fn ragged_nets(seed: u64, base: usize) -> Vec<GridNetwork> {
    let mut rng = Rng::seeded(seed);
    [
        (base, base),
        (base - base / 4, base),
        (base, base - base / 3),
        (base / 2 + 1, base / 2 + 1),
    ]
    .iter()
    .map(|&(h, w)| random_grid(&mut rng, h, w, 20, 0.3, 0.3))
    .collect()
}

fn solve_batched(nets: &[GridNetwork]) -> (Vec<i64>, BatchedGridDriver) {
    let refs: Vec<&GridNetwork> = nets.iter().collect();
    let (hmax, wmax) = padded_class(&refs);
    let mut driver = BatchedGridDriver::for_class(hmax, wmax);
    let cancels = vec![None; nets.len()];
    let flows = BatchGridSolver::with_cycle(CYCLE)
        .solve_batch(&refs, &cancels, &mut driver)
        .unwrap()
        .into_iter()
        .map(|r| r.unwrap().flow)
        .collect();
    (flows, driver)
}

fn solve_solo(nets: &[GridNetwork], engine: GridEngine) -> Vec<i64> {
    nets.iter()
        .map(|n| solve_grid_with(n, CYCLE, None, engine).unwrap().0.flow)
        .collect()
}

fn dispatch_rows(table: &mut Table, measure: &Measure, label: &str, nets: &[GridNetwork]) {
    let k = nets.len();
    // Differential contract first: batched == per-instance device ==
    // native, or the bench refuses to time a broken path.
    let (batched_flows, driver) = solve_batched(nets);
    assert_eq!(batched_flows, solve_solo(nets, GridEngine::Native), "{label}: vs native");
    assert_eq!(batched_flows, solve_solo(nets, GridEngine::Pjrt), "{label}: vs device");
    let stats = driver.stats();

    let solo_times = measure.run(|| solve_solo(nets, GridEngine::Pjrt));
    let solo = Summary::of(&solo_times).unwrap();
    let batch_times = measure.run(|| solve_batched(nets));
    let batch = Summary::of(&batch_times).unwrap();
    let speedup = solo.mean / batch.mean;

    table.row(vec![
        label.into(),
        Cell::Int(k as i64),
        "per-instance".into(),
        solo.into(),
        Cell::Float(1.0),
        Cell::Missing,
        Cell::Missing,
    ]);
    table.row(vec![
        label.into(),
        Cell::Int(k as i64),
        "batched".into(),
        batch.into(),
        Cell::Float(speedup),
        Cell::Float(stats.padding_waste()),
        Cell::Float(stats.overlap_ratio()),
    ]);
    println!(
        "{label} K={k}: batched {speedup:.2}x vs per-instance device \
         (padding waste {:.1}%, overlap {:.1}%)",
        stats.padding_waste() * 100.0,
        stats.overlap_ratio() * 100.0
    );
}

fn grid_burst(seed: u64, grids: usize, size: usize) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests: 0,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests: grids,
            grid_size: size,
            grid_max_cap: 20,
            grid_arrival_gap: 0.0,
            large_every: 0,
            ..Default::default()
        },
    )
}

fn service_row(table: &mut Table, batch_max: usize, trace: &MixedTrace) {
    let mut cfg = PoolConfig {
        workers: 2,
        ..Default::default()
    };
    cfg.router.use_pjrt = false;
    cfg.router.batch_max = batch_max;
    cfg.router.batch_linger_us = 20_000;
    let pool = SolverPool::start(cfg);
    let out = replay(&pool, trace, false);
    let report = pool.shutdown();
    assert_eq!(out.lost, 0, "batched pool lost replies");
    assert_eq!(out.ok, out.sent, "burst must be fully served");
    table.row(vec![
        Cell::Int(batch_max as i64),
        Cell::Int(out.sent as i64),
        Cell::Float(out.throughput_rps),
        match &out.grid {
            Some(s) => Cell::Float(s.p95 * 1e3),
            None => Cell::Missing,
        },
        Cell::Int(report.batches as i64),
        Cell::Int(report.batched_jobs as i64),
        Cell::Int(report.padding_waste_cells as i64),
        Cell::Int(report.linger_sheds as i64),
    ]);
}

fn main() {
    let measure = Measure::default().from_env();
    let fast = std::env::var("FLOWMATCH_BENCH_FAST").as_deref() == Ok("1");
    let size = if fast { 24 } else { 48 };
    let widths: &[usize] = if fast { &[2, 4] } else { &[1, 2, 4, 8] };
    let burst = if fast { 12 } else { 32 };

    let mut table = Table::new(
        "E16: batched device dispatch vs per-instance (host-simulated device)",
        &["set", "K", "mode", "time", "speedup", "padding waste", "overlap"],
    );
    for &k in widths {
        let nets = uniform_nets(16 + k as u64, k, size);
        dispatch_rows(&mut table, &measure, &format!("uniform {size}x{size}"), &nets);
    }
    let nets = ragged_nets(99, size);
    dispatch_rows(&mut table, &measure, "ragged", &nets);

    let mut service_table = Table::new(
        "E16: micro-batched service, closed-loop grid burst (grid p95 in ms)",
        &[
            "batch_max",
            "sent",
            "throughput rps",
            "grid p95 ms",
            "batches",
            "batched jobs",
            "padding cells",
            "linger sheds",
        ],
    );
    let trace = grid_burst(23, burst, size);
    service_row(&mut service_table, 1, &trace);
    service_row(&mut service_table, 8, &trace);

    table.print();
    service_table.print();
    let path = std::env::var("FLOWMATCH_BENCH_JSON")
        .unwrap_or_else(|_| "benches/data/bench_batch.json".to_string());
    let path = std::path::PathBuf::from(path);
    match write_json(&path, &[&table, &service_table]) {
        Ok(()) => println!("\nbenchkit JSON written to {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not write benchkit JSON: {e}"),
    }
}
