//! E1 (Fig. 1): soundness + cost of the reductions — cardinality matching
//! via max-flow, and assignment via the explicit §5 max-flow-min-cost
//! instance, against direct algorithms.

use flowmatch::assignment::{hungarian::Hungarian, AssignmentSolver};
use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::maxflow::dinic::Dinic;
use flowmatch::reductions;
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::uniform_costs;

fn main() {
    let measure = Measure::default().from_env();

    // --- matching via max flow ------------------------------------------
    let mut t1 = Table::new(
        "E1a: cardinality matching via max-flow (vs augmenting-path reference)",
        &["nx x ny", "density", "matching", "reference", "time (flow path)"],
    );
    for (nx, ny, dens, seed) in [(20, 20, 0.2, 1u64), (40, 40, 0.1, 2), (30, 50, 0.3, 3)] {
        let mut rng = Rng::seeded(seed);
        let edges: Vec<Vec<usize>> = (0..nx)
            .map(|_| (0..ny).filter(|_| rng.chance(dens)).collect())
            .collect();
        let want = reductions::matching_to_flow::reference_matching(nx, ny, &edges);
        let (size, _) = reductions::max_cardinality_matching(nx, ny, &edges, &Dinic).unwrap();
        assert_eq!(size, want);
        let times =
            measure.run(|| reductions::max_cardinality_matching(nx, ny, &edges, &Dinic).unwrap());
        t1.row(vec![
            format!("{nx}x{ny}").into(),
            Cell::Float(dens),
            Cell::Int(size as i64),
            Cell::Int(want as i64),
            Summary::of(&times).unwrap().into(),
        ]);
    }
    t1.print();

    // --- assignment via MCMF ---------------------------------------------
    let mut t2 = Table::new(
        "E1b: assignment via explicit I' + SSP (vs Hungarian)",
        &[
            "n",
            "weight (reduction)",
            "weight (hungarian)",
            "time (reduction)",
            "time (hungarian)",
        ],
    );
    for (n, seed) in [(8usize, 4u64), (16, 5), (30, 6)] {
        let mut rng = Rng::seeded(seed);
        let inst = uniform_costs(&mut rng, n, 100);
        let (_, red_w) = reductions::solve_assignment_via_mcmf(&inst).unwrap();
        let hun = Hungarian.solve(&inst).unwrap();
        assert_eq!(red_w, hun.weight);
        let tr = measure.run(|| reductions::solve_assignment_via_mcmf(&inst).unwrap());
        let th = measure.run(|| Hungarian.solve(&inst).unwrap());
        t2.row(vec![
            Cell::Int(n as i64),
            Cell::Int(red_w),
            Cell::Int(hun.weight),
            Summary::of(&tr).unwrap().into(),
            Summary::of(&th).unwrap().into(),
        ]);
    }
    t2.print();
}
