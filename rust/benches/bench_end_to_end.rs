//! E7 (claim C6, the headline): per-solve latency of the full PJRT path
//! on the paper's §6 operating point (n <= 30, costs <= 100; paper: about
//! 1/20 s on a GTX 560 Ti), plus the batched-service view with queueing.

use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::coordinator::{AssignmentService, PjrtAssignmentDriver, ServiceConfig};
use flowmatch::runtime::{transfer, ArtifactRegistry};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::{uniform_costs, RequestTrace, TraceConfig};

fn main() {
    let measure = Measure::default().from_env();
    let Ok(registry) = ArtifactRegistry::discover() else {
        println!("bench_end_to_end: no artifacts (run `make artifacts`); skipping");
        return;
    };

    // --- per-solve latency, PJRT driver ----------------------------------
    let mut table = Table::new(
        "E7a: PJRT per-solve latency (paper bar: 50 ms at n=30, C=100)",
        &[
            "n", "weight ok", "device rounds", "H2D KiB/solve", "time", "vs 50 ms",
        ],
    );
    for (n, seed) in [(8usize, 1u64), (16, 2), (30, 3)] {
        let mut rng = Rng::seeded(seed);
        let inst = uniform_costs(&mut rng, n, 100);
        let want = Hungarian.solve(&inst).unwrap().weight;
        let mut driver = PjrtAssignmentDriver::for_size(&registry, n).unwrap();
        let (got, tel) = driver.solve(&inst).unwrap();
        assert_eq!(got.weight, want);

        transfer::GLOBAL.reset();
        let times = measure.run(|| driver.solve(&inst).unwrap());
        let tx = transfer::GLOBAL.snapshot();
        let per_solve_kib = tx.h2d_bytes / 1024 / (measure.samples as u64 + measure.warmup as u64);
        let summary = Summary::of(&times).unwrap();
        let verdict = if summary.p50 <= 0.05 { "MEETS" } else { "misses" };
        table.row(vec![
            Cell::Int(n as i64),
            "yes".into(),
            Cell::Int(tel.device_rounds as i64),
            Cell::Int(per_solve_kib as i64),
            summary.clone().into(),
            format!("{verdict} ({:.1} ms p50)", summary.p50 * 1e3).into(),
        ]);
    }
    table.print();

    // --- batched service under an open-loop trace ------------------------
    let mut table = Table::new(
        "E7b: batched service, open-loop trace at 20 fps (n=30, C<=100)",
        &["requests", "backend", "p50", "p99", "mean", "throughput rps"],
    );
    for requests in [30usize, 60] {
        let cfg = TraceConfig {
            requests,
            n: 30,
            max_weight: 100,
            arrival_gap: 0.05,
            geometric_frac: 0.5,
        };
        let mut rng = Rng::seeded(42);
        let trace = RequestTrace::generate(&mut rng, &cfg);
        let service = AssignmentService::start(ServiceConfig {
            max_batch: 8,
            use_pjrt: true,
            max_n: 30,
        });
        let start = std::time::Instant::now();
        let mut receivers = Vec::new();
        for req in &trace.requests {
            let target = std::time::Duration::from_secs_f64(req.arrival);
            if let Some(wait) = target.checked_sub(start.elapsed()) {
                std::thread::sleep(wait);
            }
            receivers.push(service.submit(req.instance.clone()));
        }
        for rx in receivers {
            rx.recv().unwrap().unwrap();
        }
        let report = service.shutdown().unwrap();
        table.row(vec![
            Cell::Int(requests as i64),
            report.backend.into(),
            Cell::Float(report.p50_latency * 1e3),
            Cell::Float(report.p99_latency * 1e3),
            Cell::Float(report.mean_latency * 1e3),
            Cell::Float(report.throughput_rps),
        ]);
    }
    table.print();
    println!("(latency columns in milliseconds)");
}
