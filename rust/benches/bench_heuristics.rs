//! E3 (claim C2): global + gap relabeling ablation — the paper's "this
//! heuristic significantly improves the performance of the push-relabel
//! method" (§4.2), measured in operations and wall-clock.

use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::gridflow::{HybridGridSolver, NativeGridExecutor};
use flowmatch::maxflow::{self, MaxFlowSolver};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::random_grid;

fn main() {
    let measure = Measure::default().from_env();
    for (h, w, cap, seed) in [(16usize, 16usize, 20i64, 1u64), (32, 32, 40, 2)] {
        let mut rng = Rng::seeded(seed);
        let net = random_grid(&mut rng, h, w, cap, 0.25, 0.25);
        let base = net.to_flow_network();

        let mut table = Table::new(
            &format!("E3: heuristic ablation on grid {h}x{w} (C={cap})"),
            &["engine", "value", "pushes", "relabels", "globals", "gap nodes", "time"],
        );
        let engines: Vec<Box<dyn MaxFlowSolver>> = vec![
            Box::new(maxflow::fifo::FifoPushRelabel::generic()),
            Box::new(maxflow::fifo::FifoPushRelabel::default()),
            Box::new(maxflow::highest::HighestLabel::no_gap()),
            Box::new(maxflow::highest::HighestLabel::default()),
        ];
        for engine in engines {
            let mut g = base.clone();
            let stats = engine.solve(&mut g).unwrap();
            let times = measure.run(|| {
                let mut g = base.clone();
                engine.solve(&mut g).unwrap()
            });
            table.row(vec![
                engine.name().into(),
                Cell::Int(stats.value),
                Cell::Int(stats.pushes as i64),
                Cell::Int(stats.relabels as i64),
                Cell::Int(stats.global_relabels as i64),
                Cell::Int(stats.gap_nodes as i64),
                Summary::of(&times).unwrap().into(),
            ]);
        }

        // The wave engine with and without host heuristics (Algorithm 4.8
        // lines 1-6 + BFS vs device waves alone).
        for (name, solver) in [
            ("wave+host-heur", HybridGridSolver::with_cycle(128)),
            ("wave-no-heur", HybridGridSolver::no_heuristics(1_000_000)),
        ] {
            let mut exec = NativeGridExecutor::default();
            let report = solver.solve(&net, &mut exec).unwrap();
            let times = measure.run(|| {
                let mut exec = NativeGridExecutor::default();
                solver.solve(&net, &mut exec).unwrap()
            });
            table.row(vec![
                name.into(),
                Cell::Int(report.flow),
                Cell::Int(report.pushes),
                Cell::Int(report.relabels),
                Cell::Int(report.host_rounds as i64),
                Cell::Int(report.gap_cells as i64),
                Summary::of(&times).unwrap().into(),
            ]);
        }
        table.print();
    }
}
