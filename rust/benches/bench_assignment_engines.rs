//! E5 (claim C4): every assignment engine on the §6 workload (uniform
//! costs <= 100) — optimality parity with Hungarian, operation counts,
//! wall-clock.

use flowmatch::assignment::{self, AssignmentSolver};
use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::uniform_costs;

fn main() {
    let measure = Measure::default().from_env();
    for (n, seed) in [(8usize, 1u64), (16, 2), (30, 3)] {
        let mut rng = Rng::seeded(seed);
        let inst = uniform_costs(&mut rng, n, 100);
        let want = assignment::hungarian::Hungarian.solve(&inst).unwrap().weight;

        let mut table = Table::new(
            &format!("E5: assignment engines, n={n}, C=100 (optimum {want})"),
            &["engine", "weight", "pushes", "relabels", "refines", "time"],
        );
        for engine in assignment::all_engines() {
            let got = engine.solve(&inst).unwrap();
            assert_eq!(got.weight, want, "{}", engine.name());
            let times = measure.run(|| engine.solve(&inst).unwrap());
            table.row(vec![
                engine.name().into(),
                Cell::Int(got.weight),
                Cell::Int(got.stats.pushes as i64),
                Cell::Int(got.stats.relabels as i64),
                Cell::Int(got.stats.refines as i64),
                Summary::of(&times).unwrap().into(),
            ]);
        }
        table.print();
    }
}
