//! E8b (claim C8): wave-tile geometry — the analogue of Vineet &
//! Narayanan's 32x8 thread-block tuning and the paper's 32x16 for
//! assignment.  On this stack the tunable is K_INNER (VMEM-resident waves
//! per kernel invocation): larger K amortises invocation overhead but
//! wastes waves once locally quiescent, smaller K returns control too
//! often.  Swept for the native twin and the PJRT device (whose K_INNER
//! is baked at AOT time; its row shows outer-loop granularity instead).

use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::gridflow::{HybridGridSolver, NativeGridExecutor};
use flowmatch::runtime::{ArtifactRegistry, GridDevice};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::random_grid;

fn main() {
    let measure = Measure::quick().from_env();
    let registry = ArtifactRegistry::discover().ok();
    let (h, w) = (32usize, 32usize);
    let mut rng = Rng::seeded(7);
    let net = random_grid(&mut rng, h, w, 30, 0.25, 0.25);
    let cycle = 512;

    let mut table = Table::new(
        &format!("E8b: wave-tile (K_INNER) sweep on grid {h}x{w}, CYCLE={cycle}"),
        &["backend", "k_inner", "flow", "waves", "host rounds", "time"],
    );
    for k_inner in [1usize, 4, 16, 64, 256] {
        let solver = HybridGridSolver::with_cycle(cycle);
        let mut exec = NativeGridExecutor::with_k_inner(k_inner);
        let report = solver.solve(&net, &mut exec).unwrap();
        let times = measure.run(|| {
            let mut exec = NativeGridExecutor::with_k_inner(k_inner);
            solver.solve(&net, &mut exec).unwrap()
        });
        table.row(vec![
            "native".into(),
            Cell::Int(k_inner as i64),
            Cell::Int(report.flow),
            Cell::Int(report.waves),
            Cell::Int(report.host_rounds as i64),
            Summary::of(&times).unwrap().into(),
        ]);
    }
    if let Some(reg) = &registry {
        if let Ok(dev) = GridDevice::for_shape(reg, h, w) {
            let k = dev.k_inner;
            let solver = HybridGridSolver::with_cycle(cycle);
            let mut dev = dev;
            let report = solver.solve(&net, &mut dev).unwrap();
            let times = measure.run(|| {
                let mut dev = GridDevice::for_shape(reg, h, w).unwrap();
                solver.solve(&net, &mut dev).unwrap()
            });
            table.row(vec![
                "pjrt (AOT-baked)".into(),
                Cell::Int(k as i64),
                Cell::Int(report.flow),
                Cell::Int(report.waves),
                Cell::Int(report.host_rounds as i64),
                Summary::of(&times).unwrap().into(),
            ]);
        }
    }
    table.print();
}
