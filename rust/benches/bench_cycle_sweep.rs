//! E4 (claim C3): the CYCLE sweep — how many device waves to run between
//! host rounds.  The paper tuned CYCLE = 7000 CUDA iterations for the
//! max-flow kernel; here the sweep shows the same interior-optimum shape:
//! tiny CYCLE pays host-round + transfer overhead, huge CYCLE wastes waves
//! after local quiescence.  Both the native twin and the PJRT artifact
//! (16x16/32x32/64x64) are swept, with transfer bytes from the runtime log.

use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::gridflow::{GridExecutor, HybridGridSolver, NativeGridExecutor};
use flowmatch::runtime::{transfer, ArtifactRegistry, GridDevice};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::random_grid;

const CYCLES: &[usize] = &[16, 64, 256, 1024, 4096, 16384];

fn main() {
    let measure = Measure::quick().from_env();
    let registry = ArtifactRegistry::discover().ok();

    for (h, w, seed) in [(32usize, 32usize, 1u64), (64, 64, 2)] {
        let mut rng = Rng::seeded(seed);
        let net = random_grid(&mut rng, h, w, 30, 0.25, 0.25);

        let mut table = Table::new(
            &format!("E4: CYCLE sweep on grid {h}x{w} (waves between host rounds)"),
            &[
                "backend", "CYCLE", "flow", "host rounds", "waves", "H2D KiB", "D2H KiB", "time",
            ],
        );

        for &cycle in CYCLES {
            // Native twin.
            let solver = HybridGridSolver::with_cycle(cycle);
            let mut exec = NativeGridExecutor::default();
            let report = solver.solve(&net, &mut exec).unwrap();
            let times = measure.run(|| {
                let mut exec = NativeGridExecutor::default();
                solver.solve(&net, &mut exec).unwrap()
            });
            table.row(vec![
                "native".into(),
                Cell::Int(cycle as i64),
                Cell::Int(report.flow),
                Cell::Int(report.host_rounds as i64),
                Cell::Int(report.waves),
                Cell::Missing,
                Cell::Missing,
                Summary::of(&times).unwrap().into(),
            ]);

            // PJRT path with transfer accounting.
            if let Some(reg) = &registry {
                if let Ok(mut dev) = GridDevice::for_shape(reg, h, w) {
                    transfer::GLOBAL.reset();
                    let report = solver.solve(&net, &mut (dev)).unwrap();
                    let tx = transfer::GLOBAL.snapshot();
                    let times = measure.run(|| {
                        let mut dev = GridDevice::for_shape(reg, h, w).unwrap();
                        solver.solve(&net, &mut dev).unwrap()
                    });
                    table.row(vec![
                        "pjrt".into(),
                        Cell::Int(cycle as i64),
                        Cell::Int(report.flow),
                        Cell::Int(report.host_rounds as i64),
                        Cell::Int(report.waves),
                        Cell::Int((tx.h2d_bytes / 1024) as i64),
                        Cell::Int((tx.d2h_bytes / 1024) as i64),
                        Summary::of(&times).unwrap().into(),
                    ]);
                }
            }
            // keep the trait import used even when artifacts are absent
            let _ = GridExecutor::k_inner(&NativeGridExecutor::default());
        }
        table.print();
    }
}
