//! Parallel tiled wave engine sweep: thread-count × tile-rows × grid
//! size, sequential twin as baseline.  Measures raw wave throughput
//! (fixed wave budget on a prepared state) rather than full solves, so
//! the numbers isolate the engine the tentpole changed.
//!
//! Emits the markdown table plus benchkit JSON (default
//! `benches/data/bench_par_wave.json`, override with
//! `FLOWMATCH_BENCH_JSON`) so the next PR has a perf trajectory to
//! compare against.

use flowmatch::benchkit::{write_json, Cell, Measure, Table};
use flowmatch::gridflow::wave::{native_wave_with, WaveScratch};
use flowmatch::gridflow::{host, init_state, par_wave_with, ParWaveScratch};
use flowmatch::runtime::device::GridWireState;
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::random_grid;

/// Init + exact heights: the state every engine starts from.
fn prepared_state(seed: u64, h: usize, w: usize) -> GridWireState {
    let mut rng = Rng::seeded(seed);
    let net = random_grid(&mut rng, h, w, 30, 0.25, 0.25);
    let (mut st, _) = init_state(&net);
    host::global_relabel(&mut st);
    st
}

fn run_seq(st0: &GridWireState, waves: usize) -> i64 {
    let mut st = st0.clone();
    let mut scratch = WaveScratch::default();
    let mut pushes = 0;
    for _ in 0..waves {
        pushes += native_wave_with(&mut st, &mut scratch).pushes;
    }
    pushes
}

fn run_par(st0: &GridWireState, waves: usize, threads: usize, tile_rows: usize) -> i64 {
    let mut st = st0.clone();
    let mut scratch = ParWaveScratch::new(tile_rows);
    let mut pushes = 0;
    for _ in 0..waves {
        pushes += par_wave_with(&mut st, &mut scratch, threads).unwrap().pushes;
    }
    pushes
}

fn main() {
    let measure = Measure::default().from_env();
    let fast = std::env::var("FLOWMATCH_BENCH_FAST").as_deref() == Ok("1");
    let sizes: &[usize] = if fast { &[64, 128] } else { &[128, 256, 512] };
    let waves = 96usize;

    let mut table = Table::new(
        &format!("Parallel tiled wave engine: threads x tile_rows sweep ({waves} waves)"),
        &[
            "grid", "engine", "threads", "tile_rows", "pushes", "time", "speedup",
        ],
    );

    for &size in sizes {
        let st0 = prepared_state(9, size, size);
        let seq_pushes = run_seq(&st0, waves);
        let seq_times = measure.run(|| run_seq(&st0, waves));
        let seq_summary = Summary::of(&seq_times).unwrap();
        let seq_mean = seq_summary.mean;
        table.row(vec![
            format!("{size}x{size}").into(),
            "native".into(),
            Cell::Int(1),
            Cell::Missing,
            Cell::Int(seq_pushes),
            seq_summary.into(),
            Cell::Float(1.0),
        ]);
        for &threads in &[1usize, 2, 4] {
            for &tile_rows in &[8usize, 16, 32] {
                // The differential contract, enforced even while
                // benchmarking: identical work counters.
                let par_pushes = run_par(&st0, waves, threads, tile_rows);
                assert_eq!(
                    par_pushes, seq_pushes,
                    "parallel engine diverged at {size}x{size} t={threads} tr={tile_rows}"
                );
                let times = measure.run(|| run_par(&st0, waves, threads, tile_rows));
                let summary = Summary::of(&times).unwrap();
                let speedup = seq_mean / summary.mean;
                table.row(vec![
                    format!("{size}x{size}").into(),
                    "native-par".into(),
                    Cell::Int(threads as i64),
                    Cell::Int(tile_rows as i64),
                    Cell::Int(par_pushes),
                    summary.into(),
                    Cell::Float(speedup),
                ]);
            }
        }
    }

    table.print();
    let path = std::env::var("FLOWMATCH_BENCH_JSON")
        .unwrap_or_else(|_| "benches/data/bench_par_wave.json".to_string());
    let path = std::path::PathBuf::from(path);
    match write_json(&path, &[&table]) {
        Ok(()) => println!("\nbenchkit JSON written to {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not write benchkit JSON: {e}"),
    }
}
