//! Host-round scaling sweep (EXPERIMENTS §E11): sequential host rounds
//! vs the stripe-parallel twins on a pooled lane set, across thread
//! counts and grid sizes.  Measures full host rounds (violation cancel
//! + two-pass global relabel + height write-back) on a mid-solve state
//! reached by real waves, so the numbers isolate exactly the serial
//! fraction the striped refactor removes.
//!
//! Emits the markdown table plus benchkit JSON (default
//! `benches/data/bench_host_rounds.json`, override with
//! `FLOWMATCH_BENCH_JSON`).

use std::sync::Arc;

use flowmatch::benchkit::{write_json, Cell, Measure, Table};
use flowmatch::gridflow::wave::{native_wave_with, WaveScratch};
use flowmatch::gridflow::{host, init_state};
use flowmatch::parallel::{CommitMode, Lanes, ParTuning, StripeBalance};
use flowmatch::runtime::device::GridWireState;
use flowmatch::service::WorkerPool;
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::random_grid;

/// Init + exact heights + a burst of waves: a state with spread-out
/// excess, saturated arcs, and height violations — what a host round
/// actually sees mid-solve.
fn mid_solve_state(seed: u64, h: usize, w: usize) -> GridWireState {
    let mut rng = Rng::seeded(seed);
    let net = random_grid(&mut rng, h, w, 30, 0.25, 0.25);
    let (mut st, _) = init_state(&net);
    host::global_relabel(&mut st);
    let mut scratch = WaveScratch::default();
    for _ in 0..96 {
        native_wave_with(&mut st, &mut scratch);
    }
    st
}

const ROUNDS: usize = 4;

fn run_seq(st0: &GridWireState) -> (GridWireState, host::HostScratch) {
    let mut st = st0.clone();
    let mut scratch = host::HostScratch::for_state(&st);
    for _ in 0..ROUNDS {
        host::host_round_with(&mut st, &mut scratch);
    }
    (st, scratch)
}

fn run_striped(
    st0: &GridWireState,
    lanes: &Lanes<'_>,
    tuning: ParTuning,
) -> (GridWireState, host::HostScratch) {
    let mut st = st0.clone();
    let mut scratch = host::HostScratch::for_state(&st);
    scratch.set_tuning(tuning);
    for _ in 0..ROUNDS {
        host::host_round_par(&mut st, &mut scratch, lanes);
    }
    (st, scratch)
}

/// Phase split of one instrumented run: the scratch accumulates cancel
/// vs global-relabel seconds across the rounds, so alongside the total
/// times above the JSON also says *which* host phase the striping buys
/// back.
fn phase_row(table: &mut Table, size: usize, mode: &str, threads: usize, sc: &host::HostScratch) {
    let total = sc.cancel_seconds + sc.relabel_seconds;
    table.row(vec![
        format!("{size}x{size}").into(),
        mode.into(),
        Cell::Int(threads as i64),
        Cell::Float(sc.cancel_seconds * 1e3),
        Cell::Float(sc.relabel_seconds * 1e3),
        Cell::Float(if total > 0.0 {
            sc.relabel_seconds / total
        } else {
            0.0
        }),
    ]);
}

fn main() {
    let measure = Measure::default().from_env();
    let fast = std::env::var("FLOWMATCH_BENCH_FAST").as_deref() == Ok("1");
    let sizes: &[usize] = if fast { &[64, 128] } else { &[128, 256, 512] };

    let mut table = Table::new(
        &format!("Host rounds: seq vs striped ({ROUNDS} rounds on a mid-solve state)"),
        &["grid", "mode", "threads", "time", "speedup"],
    );
    let mut phase_table = Table::new(
        &format!("E14: host-round phase split ({ROUNDS} rounds, one instrumented run)"),
        &["grid", "mode", "threads", "cancel ms", "relabel ms", "relabel share"],
    );
    let mut tuning_table = Table::new(
        &format!("E15: stripe tunings on striped host rounds ({ROUNDS} rounds, 4 threads)"),
        &["grid", "balance", "commit", "time", "speedup vs seq"],
    );

    for &size in sizes {
        let st0 = mid_solve_state(9, size, size);
        let (seq_state, seq_scratch) = run_seq(&st0);
        phase_row(&mut phase_table, size, "seq", 1, &seq_scratch);
        let seq_times = measure.run(|| run_seq(&st0));
        let seq_summary = Summary::of(&seq_times).unwrap();
        let seq_mean = seq_summary.mean;
        table.row(vec![
            format!("{size}x{size}").into(),
            "seq".into(),
            Cell::Int(1),
            seq_summary.into(),
            Cell::Float(1.0),
        ]);
        for &threads in &[1usize, 2, 4, 8] {
            let pool = Arc::new(WorkerPool::new(threads));
            let lanes = Lanes::Pool(&pool);
            // The differential contract, enforced even while
            // benchmarking: identical post-round state.
            let (striped_state, striped_scratch) =
                run_striped(&st0, &lanes, ParTuning::default());
            phase_row(&mut phase_table, size, "striped", threads, &striped_scratch);
            assert_eq!(
                striped_state.h, seq_state.h,
                "striped host rounds diverged at {size}x{size} t={threads}"
            );
            assert_eq!(striped_state.e, seq_state.e, "excess diverged");
            assert_eq!(striped_state.cap, seq_state.cap, "caps diverged");
            let times = measure.run(|| run_striped(&st0, &lanes, ParTuning::default()));
            let summary = Summary::of(&times).unwrap();
            let speedup = seq_mean / summary.mean;
            table.row(vec![
                format!("{size}x{size}").into(),
                "striped".into(),
                Cell::Int(threads as i64),
                summary.into(),
                Cell::Float(speedup),
            ]);
        }

        // E15 rows: the opt-in stripe tunings against the default
        // two-pass/fixed discipline, all on one pooled lane set.  The
        // bit-exact contract holds for every combination — a weighted
        // re-cut or merged commit that diverged would fail right here,
        // before any timing is reported.
        let pool = Arc::new(WorkerPool::new(4));
        let lanes = Lanes::Pool(&pool);
        for (balance, commit) in [
            (StripeBalance::Fixed, CommitMode::TwoPass),
            (StripeBalance::Fixed, CommitMode::Merged),
            (StripeBalance::Weighted, CommitMode::TwoPass),
            (StripeBalance::Weighted, CommitMode::Merged),
        ] {
            let tuning = ParTuning { balance, commit };
            let (state, _) = run_striped(&st0, &lanes, tuning);
            assert_eq!(
                state.h, seq_state.h,
                "tuned host rounds diverged at {size}x{size} {balance:?}/{commit:?}"
            );
            assert_eq!(state.e, seq_state.e, "excess diverged under tuning");
            assert_eq!(state.cap, seq_state.cap, "caps diverged under tuning");
            let times = measure.run(|| run_striped(&st0, &lanes, tuning));
            let summary = Summary::of(&times).unwrap();
            let speedup = seq_mean / summary.mean;
            tuning_table.row(vec![
                format!("{size}x{size}").into(),
                balance.name().into(),
                commit.name().into(),
                summary.into(),
                Cell::Float(speedup),
            ]);
        }
    }

    table.print();
    phase_table.print();
    tuning_table.print();
    let path = std::env::var("FLOWMATCH_BENCH_JSON")
        .unwrap_or_else(|_| "benches/data/bench_host_rounds.json".to_string());
    let path = std::path::PathBuf::from(path);
    match write_json(&path, &[&table, &phase_table, &tuning_table]) {
        Ok(()) => println!("\nbenchkit JSON written to {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not write benchkit JSON: {e}"),
    }
}
