//! E9/E10: sharded solver-pool service throughput/latency.
//!
//! Three comparisons, all closed-loop:
//!
//! * **small-instance trace** (assignment n=16, the real-time class):
//!   the pooled path (persistent workers, cached solver state) against
//!   the per-request-spawn baseline (fresh thread + fresh backend
//!   state per request — the deployment shape before this subsystem).
//!   The acceptance bar is pooled ≥ 1x baseline throughput here.
//! * **mixed trace, static routing** (assignment + grids, with
//!   periodic oversized grids): the PR 3 per-size-class tables.
//! * **mixed trace, adaptive routing** (§E10): the same trace with
//!   measurement-driven routing — EWMA winners, ε-greedy probing, and
//!   saturation spill — so the JSON carries an adaptive-vs-static row
//!   pair for every later PR to diff.
//!
//! Emits benchkit JSON (default `benches/data/bench_service.json`,
//! override with `FLOWMATCH_BENCH_SERVICE_JSON`).

use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::benchkit::{write_json, Cell, Table};
use flowmatch::service::{
    replay, replay_spawn_baseline, PoolConfig, ReplayOutcome, RoutingMode, SolverPool,
};
use flowmatch::util::stats::fmt_count_pairs;
use flowmatch::util::Rng;
use flowmatch::workloads::{MixedTrace, MixedTraceConfig, ProblemInstance, TraceConfig};

fn small_trace(requests: usize, seed: u64) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests,
                n: 16,
                max_weight: 100,
                arrival_gap: 0.0,
                ..Default::default()
            },
            grid_requests: 0,
            grid_arrival_gap: 0.0,
            ..Default::default()
        },
    )
}

fn mixed_trace(requests: usize, grids: usize, seed: u64) -> MixedTrace {
    let mut rng = Rng::seeded(seed);
    MixedTrace::generate(
        &mut rng,
        &MixedTraceConfig {
            assign: TraceConfig {
                requests,
                n: 24,
                arrival_gap: 0.0,
                ..Default::default()
            },
            // Straddles the default shard boundaries: matchings Small,
            // 48² grids Medium, every 4th grid 96² = Large.
            grid_requests: grids,
            grid_size: 48,
            large_every: 4,
            large_size: 96,
            grid_arrival_gap: 0.0,
            ..Default::default()
        },
    )
}

fn row(table: &mut Table, trace: &str, path: &str, workers: i64, out: &ReplayOutcome) {
    table.row(vec![
        trace.into(),
        path.into(),
        Cell::Int(workers),
        Cell::Int(out.sent as i64),
        Cell::Int(out.ok as i64),
        Cell::Int(out.rejected as i64),
        match &out.overall {
            Some(s) => s.clone().into(),
            None => Cell::Missing,
        },
        match &out.assign {
            Some(s) => Cell::Float(s.p95 * 1e3),
            None => Cell::Missing,
        },
        match &out.assign {
            Some(s) => Cell::Float(s.p99 * 1e3),
            None => Cell::Missing,
        },
        match &out.assign {
            Some(s) => Cell::Float(s.max * 1e3),
            None => Cell::Missing,
        },
        Cell::Float(out.throughput_rps),
        Cell::Int(out.retries as i64),
        Cell::Int(out.deadline_misses as i64),
        Cell::Int(out.lost as i64),
    ]);
}

fn print_rejects(out: &ReplayOutcome) {
    if !out.reject_reasons.is_empty() {
        println!("  rejects: {}", fmt_count_pairs(&out.reject_reasons));
    }
}

/// One row per nonzero phase: where the served replies' time went,
/// client-aggregated across the whole trace (§E14).  The spawn
/// baseline traces nothing, so it contributes no rows.
fn phase_rows(table: &mut Table, trace: &str, path: &str, out: &ReplayOutcome) {
    let total = out.phases.total_seconds();
    if total <= 0.0 {
        return;
    }
    for (phase, secs) in out.phases.entries() {
        if secs > 0.0 {
            table.row(vec![
                trace.into(),
                path.into(),
                phase.into(),
                Cell::Float(secs * 1e3),
                Cell::Float(secs / total),
            ]);
        }
    }
    println!(
        "  phases [{path}]: {} (waves={} pushes={} relabels={} global_relabels={})",
        out.phases.fmt_compact(),
        out.phases.waves,
        out.phases.pushes,
        out.phases.relabels,
        out.phases.global_relabels
    );
}

fn verify_sample(trace: &MixedTrace, out: &ReplayOutcome) {
    // Spot-check optimality so the bench cannot silently measure a
    // broken path (full verification lives in integration_service.rs
    // and integration_adaptive.rs).
    for (id, reply) in out.replies.iter().take(8) {
        if let (Ok(reply), ProblemInstance::Assignment(inst)) =
            (reply, &trace.requests[*id].instance)
        {
            let want = Hungarian.solve(inst).unwrap().weight;
            assert_eq!(reply.outcome.weight(), Some(want), "request {id} not optimal");
        }
    }
}

fn main() {
    let fast = std::env::var("FLOWMATCH_BENCH_FAST").as_deref() == Ok("1");
    let small_requests = if fast { 60 } else { 240 };
    let mixed_requests = if fast { 24 } else { 80 };
    let mixed_grids = if fast { 4 } else { 12 };

    let mut table = Table::new(
        "E9/E10: solver-pool service, closed-loop (latency: overall; assign tail in ms)",
        &[
            "trace",
            "path",
            "workers",
            "sent",
            "ok",
            "rejected",
            "latency",
            "assign p95 ms",
            "assign p99 ms",
            "assign max ms",
            "throughput rps",
            "retries",
            "deadline miss",
            "lost",
        ],
    );

    let mut phase_table = Table::new(
        "E14: per-phase time split, summed over served replies",
        &["trace", "path", "phase", "total ms", "share"],
    );

    // --- small-instance trace: pooled vs per-request spawn ---------------
    let trace = small_trace(small_requests, 7);
    let cfg = PoolConfig {
        workers: 4,
        ..Default::default()
    };
    let (shard, router) = (cfg.shard.clone(), cfg.router.clone());

    let pool = SolverPool::start(cfg.clone());
    let pooled = replay(&pool, &trace, false);
    let _ = pool.shutdown();
    verify_sample(&trace, &pooled);
    row(&mut table, "small n=16", "pooled", 4, &pooled);
    phase_rows(&mut phase_table, "small n=16", "pooled", &pooled);

    let baseline = replay_spawn_baseline(&trace, &shard, &router);
    verify_sample(&trace, &baseline);
    row(
        &mut table,
        "small n=16",
        "spawn-per-request",
        baseline.sent as i64,
        &baseline,
    );

    let speedup = if pooled.wall_seconds > 0.0 {
        baseline.wall_seconds / pooled.wall_seconds
    } else {
        f64::INFINITY
    };
    println!(
        "\nsmall-instance trace: pooled {:.1} req/s vs spawn-baseline {:.1} req/s -> {speedup:.2}x",
        pooled.throughput_rps, baseline.throughput_rps
    );

    // --- mixed trace: static vs adaptive routing (E10) -------------------
    let trace = mixed_trace(mixed_requests, mixed_grids, 11);

    let pool = SolverPool::start(cfg.clone());
    let static_out = replay(&pool, &trace, false);
    let static_report = pool.shutdown();
    verify_sample(&trace, &static_out);
    print_rejects(&static_out);
    row(&mut table, "mixed asn+grid", "pooled-static", 4, &static_out);
    phase_rows(&mut phase_table, "mixed asn+grid", "pooled-static", &static_out);

    let mut adaptive_cfg = cfg;
    adaptive_cfg.router.routing = RoutingMode::Adaptive;
    let pool = SolverPool::start(adaptive_cfg);
    let adaptive_out = replay(&pool, &trace, false);
    let adaptive_report = pool.shutdown();
    verify_sample(&trace, &adaptive_out);
    print_rejects(&adaptive_out);
    row(
        &mut table,
        "mixed asn+grid",
        "pooled-adaptive",
        4,
        &adaptive_out,
    );
    phase_rows(&mut phase_table, "mixed asn+grid", "pooled-adaptive", &adaptive_out);

    for (mode, report) in [("static", &static_report), ("adaptive", &adaptive_report)] {
        println!(
            "mixed trace [{mode}] backends: [{}] spilled={}",
            fmt_count_pairs(&report.backends),
            report.spilled
        );
    }

    table.print();
    phase_table.print();
    let path = std::env::var("FLOWMATCH_BENCH_SERVICE_JSON")
        .unwrap_or_else(|_| "benches/data/bench_service.json".to_string());
    let path = std::path::PathBuf::from(path);
    match write_json(&path, &[&table, &phase_table]) {
        Ok(()) => println!("\nbenchkit JSON written to {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not write benchkit JSON: {e}"),
    }
}
