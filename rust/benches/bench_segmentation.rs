//! E9 (§1/§4 application): MAP-MRF segmentation through the KZ
//! construction — hybrid wave pipeline vs sequential baselines across
//! image sizes, with energy parity asserted.

use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::energy::segmentation::{segment_image, segment_image_baseline};
use flowmatch::gridflow::NativeGridExecutor;
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::grid_gen::synthetic_image;

fn main() {
    let measure = Measure::quick().from_env();
    let mut table = Table::new(
        "E9: graph-cut segmentation (KZ construction), hybrid vs Dinic",
        &["image", "energy", "fg px", "hybrid time", "dinic time"],
    );
    for (side, seed) in [(16usize, 1u64), (24, 2), (32, 3), (48, 4)] {
        let mut rng = Rng::seeded(seed);
        let img = synthetic_image(&mut rng, side, side);
        let mut exec = NativeGridExecutor::default();
        let a = segment_image(&img, side, side, 12, &mut exec).unwrap();
        let b = segment_image_baseline(&img, side, side, 12).unwrap();
        assert_eq!(a.energy, b.energy, "{side}x{side}");

        let th = measure.run(|| {
            let mut exec = NativeGridExecutor::default();
            segment_image(&img, side, side, 12, &mut exec).unwrap()
        });
        let td = measure.run(|| segment_image_baseline(&img, side, side, 12).unwrap());
        table.row(vec![
            format!("{side}x{side}").into(),
            Cell::Int(a.energy),
            Cell::Int(a.foreground as i64),
            Summary::of(&th).unwrap().into(),
            Summary::of(&td).unwrap().into(),
        ]);
    }
    table.print();
}
