//! E6 (claim C5): the ALPHA sweep — the paper chose ALPHA = 10 "because
//! in our tests other values much extended the running time" (§5.5).
//! Swept for the sequential engine (with heuristics) and the wave engine.

use flowmatch::assignment::csa::SequentialCsa;
use flowmatch::assignment::wave::WaveCsa;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::uniform_costs;

const ALPHAS: &[i64] = &[2, 4, 8, 10, 16, 32, 64];

fn main() {
    let measure = Measure::default().from_env();
    for (n, seed) in [(30usize, 1u64), (64, 2)] {
        let mut rng = Rng::seeded(seed);
        let inst = uniform_costs(&mut rng, n, 100);

        let mut table = Table::new(
            &format!("E6: ALPHA sweep, n={n}, C=100"),
            &[
                "alpha",
                "refines",
                "seq ops",
                "seq time",
                "wave waves",
                "wave time",
            ],
        );
        for &alpha in ALPHAS {
            let seq = SequentialCsa::with_alpha(alpha).solve(&inst).unwrap();
            let wave = WaveCsa { alpha: Some(alpha) }.solve(&inst).unwrap();
            assert_eq!(seq.weight, wave.weight, "alpha={alpha}");
            let ts = measure.run(|| SequentialCsa::with_alpha(alpha).solve(&inst).unwrap());
            let tw = measure.run(|| WaveCsa { alpha: Some(alpha) }.solve(&inst).unwrap());
            table.row(vec![
                Cell::Int(alpha),
                Cell::Int(seq.stats.refines as i64),
                Cell::Int((seq.stats.pushes + seq.stats.relabels) as i64),
                Summary::of(&ts).unwrap().into(),
                Cell::Int(wave.stats.waves as i64),
                Summary::of(&tw).unwrap().into(),
            ]);
        }
        table.print();
    }
}
