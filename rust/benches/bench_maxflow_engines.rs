//! E2 (claim C1): every max-flow engine on every workload family —
//! value parity, operation counts vs the O(V²E) envelope, wall-clock.

use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::graph::FlowNetwork;
use flowmatch::maxflow;
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::{random_grid, rmf_network};

fn workloads() -> Vec<(String, FlowNetwork)> {
    let mut out = Vec::new();
    for (h, w, cap, seed) in [(16usize, 16usize, 30i64, 1u64), (32, 32, 30, 2)] {
        let mut rng = Rng::seeded(seed);
        out.push((
            format!("grid {h}x{w} C={cap}"),
            random_grid(&mut rng, h, w, cap, 0.25, 0.25).to_flow_network(),
        ));
    }
    let mut rng = Rng::seeded(3);
    out.push(("rmf a=4 f=5".to_string(), rmf_network(&mut rng, 4, 5, 20)));
    let mut rng = Rng::seeded(4);
    out.push(("rmf a=6 f=4".to_string(), rmf_network(&mut rng, 6, 4, 20)));
    out
}

fn main() {
    let measure = Measure::default().from_env();
    for (wname, base) in workloads() {
        let n = base.node_count() as u64;
        let m = (base.edge_pair_count() * 2) as u64;
        let bound = n * n * m;
        let mut table = Table::new(
            &format!("E2: max-flow engines on {wname} (V={n}, E={m}; V²E={bound})"),
            &["engine", "value", "pushes", "relabels", "work/V²E", "time"],
        );
        let mut reference = None;
        for engine in maxflow::all_engines() {
            let mut g = base.clone();
            let stats = engine.solve(&mut g).unwrap();
            flowmatch::graph::validate::assert_max_flow(&g, stats.value)
                .unwrap_or_else(|e| panic!("{}: {e}", engine.name()));
            match reference {
                None => reference = Some(stats.value),
                Some(v) => assert_eq!(v, stats.value, "{}", engine.name()),
            }
            let times = measure.run(|| {
                let mut g = base.clone();
                engine.solve(&mut g).unwrap()
            });
            table.row(vec![
                engine.name().into(),
                Cell::Int(stats.value),
                Cell::Int(stats.pushes as i64),
                Cell::Int(stats.relabels as i64),
                Cell::Float(stats.work() as f64 / bound as f64),
                Summary::of(&times).unwrap().into(),
            ]);
        }
        table.print();
    }
}
