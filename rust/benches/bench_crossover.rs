//! E8 (claim C7): the sequential/parallel crossover — §6: "In the case of
//! the large complete bipartite graphs the presented algorithm is not
//! efficient."  On a serial host the data-parallel wave engine pays
//! O(n²) work per wave; the sequential double-scan engine does targeted
//! work.  The table reports the time ratio as n grows — the paper's shape
//! is the growing ratio (parallel loses ground with size when parallel
//! hardware does not scale with the instance).

use flowmatch::assignment::csa::SequentialCsa;
use flowmatch::assignment::csa_lockfree::LockFreeCsa;
use flowmatch::assignment::hungarian::Hungarian;
use flowmatch::assignment::wave::WaveCsa;
use flowmatch::assignment::AssignmentSolver;
use flowmatch::benchkit::{Cell, Measure, Table};
use flowmatch::util::stats::Summary;
use flowmatch::util::Rng;
use flowmatch::workloads::uniform_costs;

fn main() {
    let measure = Measure::quick().from_env();
    let mut table = Table::new(
        "E8: sequential vs parallel-style engines as n grows (C=100)",
        &[
            "n",
            "hungarian",
            "csa-seq",
            "csa-lockfree(2)",
            "csa-wave",
            "wave/seq ratio",
        ],
    );
    for (n, seed) in [(8usize, 1u64), (16, 2), (30, 3), (48, 4), (64, 5)] {
        let mut rng = Rng::seeded(seed);
        let inst = uniform_costs(&mut rng, n, 100);
        let want = Hungarian.solve(&inst).unwrap().weight;
        for engine in [
            &SequentialCsa::default() as &dyn AssignmentSolver,
            &LockFreeCsa::default(),
            &WaveCsa::default(),
        ] {
            assert_eq!(engine.solve(&inst).unwrap().weight, want);
        }
        let th = Summary::of(&measure.run(|| Hungarian.solve(&inst).unwrap())).unwrap();
        let ts =
            Summary::of(&measure.run(|| SequentialCsa::default().solve(&inst).unwrap())).unwrap();
        let tl =
            Summary::of(&measure.run(|| LockFreeCsa::default().solve(&inst).unwrap())).unwrap();
        let tw = Summary::of(&measure.run(|| WaveCsa::default().solve(&inst).unwrap())).unwrap();
        table.row(vec![
            Cell::Int(n as i64),
            th.into(),
            ts.clone().into(),
            tl.into(),
            tw.clone().into(),
            Cell::Float(tw.mean / ts.mean.max(1e-12)),
        ]);
    }
    table.print();
    println!("(growing wave/seq ratio = the paper's §6 large-graph caveat)");
}
